//! Experiment configuration: a TOML-subset parser plus typed experiment
//! configs. `serde`/`toml` are not available offline, so HeterPS parses the
//! subset it needs itself: `[section]` headers, `key = value` pairs with
//! string / float / int / bool / flat-array values, `#` comments.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with line context.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed config: `section.key -> Value`. Keys outside any section live
/// under the empty section name.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse from a string.
    pub fn parse(text: &str) -> Result<Config, ParseError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ParseError {
                        line: ln + 1,
                        message: "unterminated section header".into(),
                    });
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(ParseError { line: ln + 1, message: "empty section name".into() });
                }
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: ln + 1,
                message: "expected `key = value`".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ParseError { line: ln + 1, message: "empty key".into() });
            }
            let value = parse_value(line[eq + 1..].trim(), ln + 1)?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            cfg.values.insert(full, value);
        }
        Ok(cfg)
    }

    /// Parse from a file path.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Ok(Config::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_i64).map(|i| i as usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All keys under a section prefix (e.g. "resources.").
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        self.values.keys().filter(|k| k.starts_with(prefix)).map(|k| k.as_str()).collect()
    }

    pub fn insert(&mut self, key: &str, value: Value) {
        self.values.insert(key.to_string(), value);
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<Value, ParseError> {
    let err = |m: &str| ParseError { line, message: m.to_string() };
    if text.is_empty() {
        return Err(err("empty value"));
    }
    if text.starts_with('"') {
        if text.len() < 2 || !text.ends_with('"') {
            return Err(err("unterminated string"));
        }
        return Ok(Value::Str(text[1..text.len() - 1].to_string()));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if text.starts_with('[') {
        if !text.ends_with(']') {
            return Err(err("unterminated array"));
        }
        let inner = text[1..text.len() - 1].trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), line)?);
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(&format!("cannot parse value `{text}`")))
}

/// Split on commas that are not inside quotes or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let cfg = Config::parse(
            "top = 1\n[cluster]\nname = \"dev\" # trailing comment\ncpu_servers = 10\nprice = 0.04\nelastic = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get("top").unwrap().as_i64(), Some(1));
        assert_eq!(cfg.str_or("cluster.name", "?"), "dev");
        assert_eq!(cfg.usize_or("cluster.cpu_servers", 0), 10);
        assert!((cfg.f64_or("cluster.price", 0.0) - 0.04).abs() < 1e-12);
        assert!(cfg.bool_or("cluster.elastic", false));
    }

    #[test]
    fn parses_arrays() {
        let cfg = Config::parse("xs = [1, 2.5, \"a,b\", [3, 4]]").unwrap();
        let arr = cfg.get("xs").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].as_str(), Some("a,b"));
        assert_eq!(arr[3].as_array().unwrap().len(), 2);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[open").is_err());
        assert!(Config::parse("keyonly").is_err());
        assert!(Config::parse("k = ").is_err());
        assert!(Config::parse("k = \"open").is_err());
        assert!(Config::parse("k = [1, 2").is_err());
        assert!(Config::parse("k = what").is_err());
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.str_or("a.b", "dflt"), "dflt");
        assert_eq!(cfg.usize_or("a.c", 7), 7);
    }

    #[test]
    fn comment_inside_string_is_kept() {
        let cfg = Config::parse("k = \"a # b\"").unwrap();
        assert_eq!(cfg.get("k").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn keys_under_prefix() {
        let cfg = Config::parse("[r]\na = 1\nb = 2\n[s]\nc = 3").unwrap();
        let keys = cfg.keys_under("r.");
        assert_eq!(keys, vec!["r.a", "r.b"]);
    }
}
