//! Foundational substrates shared by the whole framework.
//!
//! Everything here exists because the build is fully offline and only the
//! `xla` crate's vendor tree is available: deterministic RNG (`rand` is
//! absent), tiny linear algebra for the GP surrogate (no BLAS), statistics
//! for the profiler/bench harness (no `criterion`), and a property-testing
//! harness (no `proptest`).

pub mod json;
pub mod matrix;
pub mod propcheck;
pub mod rng;
pub mod stats;

/// Softmax over a slice, numerically stabilized by max subtraction.
pub fn softmax(logits: &[f64]) -> Vec<f64> {
    if logits.is_empty() {
        return Vec::new();
    }
    let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|x| (x - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Argmax index; ties resolve to the first maximum. Empty slice -> 0.
pub fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

/// Format a duration in seconds like the paper's tables (3 significant
/// figures, seconds).
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 1e-3 {
        format!("{:.3}", s)
    } else {
        format!("{:.4}", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[1001.0, 1002.0, 1003.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
