//! Minimal JSON parsing and rendering. `serde_json` is not available
//! offline, so this is a compact recursive-descent parser plus a
//! renderer, sized for the small machine-readable surfaces the crate
//! owns: the serve daemon's JSONL arrival streams, its `--json-out`
//! report, and the merged `results/BENCH_perf.json` bench artifact.
//!
//! Scope (documented, deliberate):
//!
//! * Object keys keep **insertion order** (`Vec<(String, Json)>`, not a
//!   map), so rendered artifacts diff stably across runs.
//! * Numbers are `f64`. Non-finite values render as `null` (JSON has no
//!   NaN/inf).
//! * `\uXXXX` escapes are decoded, including surrogate pairs; anything
//!   else malformed is a positioned error, never a silent fallback.

use std::fmt::Write as _;

/// Maximum container nesting the parser accepts. Operator-supplied
/// files (`trace-profile`, `trace-lint`, `bench-diff`) go through this
/// parser, and recursive descent turns adversarial nesting into a stack
/// overflow — an abort, not a catchable error — so depth is bounded
/// here with a positioned [`JsonError`] instead. Every artifact the
/// crate itself emits nests a handful of levels deep.
pub const MAX_DEPTH: usize = 64;

/// One JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered members.
    Obj(Vec<(String, Json)>),
}

/// Parse error with the character offset it occurred at.
#[derive(Debug, thiserror::Error)]
#[error("{msg} at offset {at}")]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let chars: Vec<char> = text.chars().collect();
        let mut p = Parser { chars, pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos < p.chars.len() {
            return Err(p.err("trailing characters after the JSON value"));
        }
        Ok(v)
    }

    /// Member lookup on an object (first match); `None` elsewhere.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&Vec<Json>> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&Vec<(String, Json)>> {
        match self {
            Json::Obj(members) => Some(members),
            _ => None,
        }
    }

    /// Human word for the value's type (error messages).
    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "a boolean",
            Json::Num(_) => "a number",
            Json::Str(_) => "a string",
            Json::Arr(_) => "an array",
            Json::Obj(_) => "an object",
        }
    }

    /// Compact single-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering, 2-space indent, trailing newline.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..w * d {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_none() {
                            out.push(' ');
                        }
                    }
                    pad(out, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(members) => {
                if members.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_none() {
                            out.push(' ');
                        }
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent, depth + 1);
                }
                pad(out, depth);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
    depth: usize,
}

impl Parser {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { at: self.pos, msg: msg.into() }
    }

    /// Entering a container (`[` / `{`); errors past [`MAX_DEPTH`].
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(format!(
                "JSON nested deeper than the supported maximum depth of {MAX_DEPTH}"
            )));
        }
        Ok(())
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: char) -> Result<(), JsonError> {
        match self.bump() {
            Some(got) if got == c => Ok(()),
            Some(got) => Err(JsonError {
                at: self.pos - 1,
                msg: format!("expected `{c}`, found `{got}`"),
            }),
            None => Err(self.err(format!("expected `{c}`, found end of input"))),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        for c in word.chars() {
            self.expect(c)?;
        }
        Ok(value)
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            None => Err(self.err("expected a JSON value, found end of input")),
            Some('n') => self.literal("null", Json::Null),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('"') => self.string().map(Json::Str),
            Some('[') => self.array(),
            Some('{') => self.object(),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character `{c}`"))),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some('-' | '+' | '.' | 'e' | 'E' | '0'..='9')
        ) {
            self.pos += 1;
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        text.parse::<f64>().map(Json::Num).map_err(|_| JsonError {
            at: start,
            msg: format!("invalid number `{text}`"),
        })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    None => return Err(self.err("unterminated escape")),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('b') => out.push('\u{8}'),
                    Some('f') => out.push('\u{c}'),
                    Some('n') => out.push('\n'),
                    Some('r') => out.push('\r'),
                    Some('t') => out.push('\t'),
                    Some('u') => out.push(self.unicode_escape()?),
                    Some(c) => {
                        return Err(self.err(format!("unknown escape `\\{c}`")));
                    }
                },
                Some(c) => out.push(c),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self
                .bump()
                .ok_or_else(|| self.err("unterminated \\u escape"))?;
            let d = c
                .to_digit(16)
                .ok_or_else(|| self.err(format!("invalid hex digit `{c}` in \\u escape")))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4()?;
        let code = if (0xD800..0xDC00).contains(&hi) {
            // High surrogate: a \uXXXX low surrogate must follow.
            if self.bump() != Some('\\') || self.bump() != Some('u') {
                return Err(self.err("high surrogate not followed by \\u escape"));
            }
            let lo = self.hex4()?;
            if !(0xDC00..0xE000).contains(&lo) {
                return Err(self.err("invalid low surrogate in \\u escape pair"));
            }
            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
        } else {
            hi
        };
        char::from_u32(code).ok_or_else(|| self.err("invalid \\u escape code point"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect('[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some(']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                Some(c) => {
                    return Err(self.err(format!("expected `,` or `]` in array, found `{c}`")));
                }
                None => return Err(self.err("unterminated array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect('{')?;
        self.enter()?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            self.skip_ws();
            let value = self.value()?;
            members.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some('}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(members));
                }
                Some(c) => {
                    return Err(self.err(format!("expected `,` or `}}` in object, found `{c}`")));
                }
                None => return Err(self.err("unterminated object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        let v = Json::parse(r#"{"at": 1, "tags": ["x", 2], "ok": false}"#).unwrap();
        assert_eq!(v.get("at").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("tags").and_then(Json::as_arr).map(Vec::len), Some(2));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn unicode_escapes_round_trip() {
        let v = Json::parse(r#""\u00e9 \ud83d\ude00""#).unwrap();
        assert_eq!(v, Json::Str("é 😀".into()));
        // Control characters are escaped on the way out.
        let s = Json::Str("a\u{1}b".into()).render();
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(Json::parse(&s).unwrap(), Json::Str("a\u{1}b".into()));
    }

    #[test]
    fn errors_carry_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.to_string().contains("offset 6"), "{e}");
        assert!(Json::parse("[1, 2").unwrap_err().to_string().contains("unterminated"));
        assert!(Json::parse("nulL").is_err());
        assert!(Json::parse("1 2").unwrap_err().to_string().contains("trailing"));
        assert!(Json::parse("\"\\ud800x\"").is_err(), "lone surrogate must not parse");
    }

    #[test]
    fn renders_round_trip_numbers_exactly() {
        // `{}` on f64 prints the shortest string that parses back to the
        // same bits — the property the JSONL stream determinism relies on.
        for v in [0.0, 1.0, 0.1, 1234.5678, 3.2e7, f64::MIN_POSITIVE] {
            let text = Json::Num(v).render();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{text}");
        }
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn non_finite_numbers_render_as_null_and_round_trip() {
        // JSON has no literal for NaN/±inf; they render as `null` so a
        // half-measured bench row or metrics dump stays parseable.
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = Json::Num(v).render();
            assert_eq!(text, "null", "{v}");
            assert_eq!(Json::parse(&text).unwrap(), Json::Null);
        }
        // Nested: the null survives a full render → parse → render cycle.
        let obj = Json::Obj(vec![
            ("lo".into(), Json::Num(f64::NEG_INFINITY)),
            ("hi".into(), Json::Num(f64::INFINITY)),
            ("ok".into(), Json::Num(2.5)),
        ]);
        let text = obj.render();
        assert_eq!(text, "{\"lo\": null, \"hi\": null, \"ok\": 2.5}");
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("lo"), Some(&Json::Null));
        assert_eq!(back.get("hi"), Some(&Json::Null));
        assert_eq!(back.render(), text);
    }

    #[test]
    fn nesting_depth_is_bounded_with_a_named_limit() {
        // Exactly MAX_DEPTH levels parse; one more is a positioned error
        // naming the limit, not a recursion-driven stack overflow.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let too_deep = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let e = Json::parse(&too_deep).unwrap_err();
        assert!(e.to_string().contains("maximum depth of 64"), "{e}");
        // Objects count against the same budget as arrays.
        let mixed = format!(
            "{}{}1{}{}",
            "{\"k\": ".repeat(40),
            "[".repeat(40),
            "]".repeat(40),
            "}".repeat(40)
        );
        let e = Json::parse(&mixed).unwrap_err();
        assert!(e.to_string().contains("maximum depth of 64"), "{e}");
        // Siblings do not accumulate: depth is nesting, not total count.
        let wide = format!("[{}]", vec!["[1]"; 500].join(", "));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn object_order_is_preserved_and_pretty_parses() {
        let v = Json::Obj(vec![
            ("z".into(), Json::Num(1.0)),
            ("a".into(), Json::Arr(vec![Json::Null, Json::Bool(true)])),
        ]);
        let compact = v.render();
        assert!(compact.starts_with("{\"z\""), "{compact}");
        let pretty = v.render_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert_eq!(Json::parse(&compact).unwrap(), v);
    }
}
