//! Small statistics helpers used by the bench harness, the profiler and the
//! schedulers (BO needs means/variances; the bench harness reports
//! criterion-style summaries without criterion being available offline).

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n-1 denominator). 0.0 when n < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; `q` in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min of a slice (0.0 when empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
}

/// Max of a slice (0.0 when empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Ordinary least squares fit of `y = a + b*x`; returns `(a, b)`.
///
/// The profiler uses this to fit Amdahl's-law serial fractions from
/// (1/k, time) observations: `T(k) = T_serial + T_parallel / k` is linear
/// in `1/k`.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linfit needs >= 2 points");
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (sy / n, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Exponential moving average state, used for the REINFORCE baseline
/// (Algorithm 1, line 8: `b <- (1-gamma)*b + gamma*mean(R)`).
#[derive(Clone, Debug)]
pub struct Ema {
    value: f64,
    gamma: f64,
    initialized: bool,
}

impl Ema {
    /// `gamma` is the update rate in (0, 1].
    pub fn new(gamma: f64) -> Self {
        assert!(gamma > 0.0 && gamma <= 1.0);
        Ema { value: 0.0, gamma, initialized: false }
    }

    /// Fold in a new observation and return the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        if self.initialized {
            self.value = (1.0 - self.gamma) * self.value + self.gamma * x;
        } else {
            // Seed with the first observation instead of 0 to avoid a long
            // warm-up bias in the advantage estimate.
            self.value = x;
            self.initialized = true;
        }
        self.value
    }

    pub fn get(&self) -> f64 {
        self.value
    }
}

/// Welford online mean/variance accumulator (profiler timing streams).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 40.0).abs() < 1e-12);
        assert!((median(&xs) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_amdahl_shape() {
        // T(k) = 2 + 8/k, sampled at k = 1,2,4,8 -> fit against x = 1/k.
        let ks = [1.0, 2.0, 4.0, 8.0];
        let xs: Vec<f64> = ks.iter().map(|k| 1.0 / k).collect();
        let ys: Vec<f64> = ks.iter().map(|k| 2.0 + 8.0 / k).collect();
        let (serial, parallel) = linfit(&xs, &ys);
        assert!((serial - 2.0).abs() < 1e-9);
        assert!((parallel - 8.0).abs() < 1e-9);
    }

    #[test]
    fn ema_seeds_with_first_value() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        assert!((e.update(20.0) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
