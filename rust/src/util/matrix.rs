//! Dense column-major-free small matrix algebra for the Bayesian-optimization
//! scheduler's Gaussian-process surrogate: Cholesky factorization, triangular
//! solves and matrix-vector products. No BLAS is available offline; the GP
//! operates on at most a few hundred observed scheduling plans, so a simple
//! O(n^3) Cholesky is more than fast enough.

/// Row-major dense matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Lower-triangular Cholesky factor `L` with `A = L L^T`.
///
/// Returns `None` when `A` is not (numerically) positive definite; the GP
/// caller responds by increasing jitter on the diagonal.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    y
}

/// Solve `L^T x = y` for lower-triangular `L` (backward substitution).
pub fn solve_upper_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    x
}

/// Solve `A x = b` via Cholesky; `A` must be SPD.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b);
    Some(solve_upper_t(&l, &y))
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared Euclidean distance.
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-9
    }

    #[test]
    fn cholesky_of_known_matrix() {
        // A = [[4,2],[2,3]] => L = [[2,0],[1,sqrt(2)]]
        let a = Mat::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        assert!(approx(l[(0, 0)], 2.0));
        assert!(approx(l[(1, 0)], 1.0));
        assert!(approx(l[(1, 1)], 2f64.sqrt()));
        assert!(approx(l[(0, 1)], 0.0));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_solve_roundtrip() {
        let a = Mat::from_rows(&[
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!(approx(*xi, *ti), "{x:?}");
        }
    }

    #[test]
    fn triangular_solves_invert_each_other() {
        let a = Mat::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        let b = vec![10.0, 7.0];
        let y = solve_lower(&l, &b);
        let x = solve_upper_t(&l, &y);
        let back = a.matvec(&x);
        assert!(approx(back[0], 10.0) && approx(back[1], 7.0));
    }

    #[test]
    fn identity_solves_trivially() {
        let a = Mat::identity(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(solve_spd(&a, &b).unwrap(), b);
    }

    #[test]
    fn matvec_basic() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn dot_and_sqdist() {
        assert!(approx(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0));
        assert!(approx(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0));
    }
}
