//! Minimal property-based testing harness.
//!
//! `proptest` is not in the offline vendor tree, so HeterPS ships a small
//! equivalent: run a property against many seeded random inputs and, on
//! failure, report the failing case and the seed that reproduces it.
//! Generation is driven by the library's own [`Rng`](super::rng::Rng) so
//! failures are deterministic across runs.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` against `cases` inputs drawn by `gen` from seeds derived from
/// `seed`. Panics (test failure) with the failing case's debug rendering and
/// the exact per-case seed on the first counterexample.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property falsified (case {case}/{cases}, seed {case_seed:#x}):\n{input:#?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` for a
/// descriptive failure message.
pub fn check_result<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property falsified (case {case}/{cases}, seed {case_seed:#x}): {msg}\n{input:#?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    /// Vec of length in `[min_len, max_len]` with elements from `f`.
    pub fn vec_of<T>(
        rng: &mut Rng,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Rng) -> T,
    ) -> Vec<T> {
        let n = rng.range(min_len, max_len + 1);
        (0..n).map(|_| f(rng)).collect()
    }

    /// f64 in [lo, hi).
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        lo + rng.f64() * (hi - lo)
    }

    /// usize in [lo, hi).
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0usize;
        check(
            1,
            64,
            |rng| rng.below(100),
            |x| {
                ran += 1;
                *x < 100
            },
        );
        assert_eq!(ran, 64);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_panics_with_case() {
        check(2, 64, |rng| rng.below(10), |x| *x < 5);
    }

    #[test]
    fn generators_respect_bounds() {
        check(
            3,
            128,
            |rng| {
                (
                    gen::vec_of(rng, 1, 8, |r| gen::f64_in(r, -1.0, 1.0)),
                    gen::usize_in(rng, 3, 9),
                )
            },
            |(v, u)| {
                (1..=8).contains(&v.len())
                    && v.iter().all(|x| (-1.0..1.0).contains(x))
                    && (3..9).contains(u)
            },
        );
    }
}
