//! Deterministic pseudo-random number generation.
//!
//! The offline vendor tree has no `rand` crate, so HeterPS ships its own
//! small, well-tested generator: SplitMix64 for seeding and xoshiro256++
//! for the stream. Every stochastic component in the framework (genetic
//! search, BO sampling, REINFORCE action sampling, synthetic data) takes an
//! explicit [`Rng`] so experiments are reproducible from a single seed.

/// xoshiro256++ PRNG seeded via SplitMix64.
///
/// Passes BigCrush per the reference implementation by Blackman & Vigna;
/// more than adequate for scheduling search and synthetic data.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
            // Extremely rare rejection path; resample.
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Sample an index from a (not necessarily normalized) weight vector.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-thread use).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Zipf-distributed value in `[0, n)` with exponent `s` (rejection
    /// inversion). Used by the synthetic CTR feature generator — sparse
    /// feature popularity is heavily skewed in production click logs.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        // Inverse-CDF on the harmonic approximation; exact enough for data
        // synthesis and O(1) per draw.
        let nf = n as f64;
        if (s - 1.0).abs() < 1e-9 {
            let h = nf.ln();
            let u = self.f64() * h;
            return ((u.exp() - 1.0).max(0.0).min(nf - 1.0)) as usize;
        }
        let a = 1.0 - s;
        let h = (nf.powf(a) - 1.0) / a;
        let u = self.f64() * h;
        let x = (u * a + 1.0).powf(1.0 / a) - 1.0;
        (x.max(0.0).min(nf - 1.0)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [0.1, 0.1, 10.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert!(c[2] > 9_000, "c={c:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skewed_to_small_values() {
        let mut r = Rng::new(17);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if r.zipf(1000, 1.1) < 10 {
                head += 1;
            }
        }
        // Head of the distribution carries a disproportionate share.
        assert!(head > n / 5, "head={head}");
    }

    #[test]
    fn zipf_in_bounds() {
        let mut r = Rng::new(19);
        for _ in 0..5000 {
            assert!(r.zipf(100, 0.8) < 100);
            assert!(r.zipf(1, 1.2) == 0);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(23);
        let mut b = a.fork();
        let av: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
