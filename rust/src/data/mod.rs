//! Data management (§3): the module that feeds the training pipeline and
//! keeps parameter state on the right storage tier.
//!
//! * [`dataset`] — synthetic CTR click-log generator (zipfian sparse slots
//!   + dense features), standing in for the paper's production logs.
//! * [`cache`] — the prefetching LRU cache that stages training batches in
//!   CPU-worker memory ahead of consumption.
//! * [`hotcold`] — access-frequency-tiered parameter storage (hot rows in
//!   memory, cold rows spilled to SSD), §3's hot/cold parameter monitor.
//! * [`compress`] — communication aggregation + compression (fp16
//!   quantization and sparse delta encoding) for inter-worker traffic.

pub mod cache;
pub mod compress;
pub mod dataset;
pub mod loader;
pub mod hotcold;

pub use cache::PrefetchCache;
pub use loader::PrefetchLoader;
pub use compress::{compress_f32, decompress_f32, Codec};
pub use dataset::{Batch, CtrDataset, DatasetConfig};
pub use hotcold::HotColdStore;
