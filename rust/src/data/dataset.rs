//! Synthetic CTR click-log generator.
//!
//! The paper trains on production click logs (~10 TB, §1) whose defining
//! property is *sparse-feature skew*: a handful of feature ids dominate
//! accesses. The generator reproduces that regime with zipfian slot draws
//! so the embedding path (lookups, hot/cold tiering, PS traffic) exercises
//! the same behaviour; see DESIGN.md §Hardware-Adaptation.

use crate::util::rng::Rng;

/// Shape of the synthetic dataset.
#[derive(Clone, Debug)]
pub struct DatasetConfig {
    /// Sparse slots per example (each yields one id into the shared vocab).
    pub slots: usize,
    /// Vocabulary size of the embedding table.
    pub vocab: usize,
    /// Zipf exponent of id popularity (production logs: ~1.0–1.3).
    pub zipf_exponent: f64,
    /// Dense features per example.
    pub dense_dim: usize,
    /// Base CTR used for label generation.
    pub base_ctr: f64,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig { slots: 26, vocab: 1_000_000, zipf_exponent: 1.1, dense_dim: 13, base_ctr: 0.2 }
    }
}

/// One mini-batch of examples.
#[derive(Clone, Debug)]
pub struct Batch {
    pub size: usize,
    /// `size * slots` sparse ids, row-major.
    pub sparse_ids: Vec<u32>,
    /// `size * dense_dim` dense features.
    pub dense: Vec<f32>,
    /// `size` click labels in {0, 1}.
    pub labels: Vec<f32>,
}

impl Batch {
    pub fn ids_of(&self, row: usize, slots: usize) -> &[u32] {
        &self.sparse_ids[row * slots..(row + 1) * slots]
    }
}

/// Deterministic synthetic click-log stream.
pub struct CtrDataset {
    pub cfg: DatasetConfig,
    rng: Rng,
    /// Hidden per-slot weights so labels carry real signal a model can fit.
    slot_weight: Vec<f32>,
    dense_weight: Vec<f32>,
}

impl CtrDataset {
    pub fn new(cfg: DatasetConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let slot_weight = (0..cfg.slots).map(|_| rng.normal() as f32 * 0.5).collect();
        let dense_weight = (0..cfg.dense_dim).map(|_| rng.normal() as f32 * 0.5).collect();
        CtrDataset { cfg, rng, slot_weight, dense_weight }
    }

    /// Draw the next batch. Labels are a logistic function of a hidden
    /// linear model over (hashed id parity, dense features) plus noise, so
    /// training loss genuinely decreases for a learner.
    pub fn next_batch(&mut self, size: usize) -> Batch {
        let cfg = self.cfg.clone();
        let mut sparse_ids = Vec::with_capacity(size * cfg.slots);
        let mut dense = Vec::with_capacity(size * cfg.dense_dim);
        let mut labels = Vec::with_capacity(size);
        for _ in 0..size {
            let mut logit = (self.cfg.base_ctr / (1.0 - self.cfg.base_ctr)).ln() as f32;
            for s in 0..cfg.slots {
                let id = self.rng.zipf(cfg.vocab, cfg.zipf_exponent) as u32;
                sparse_ids.push(id);
                // Hidden signal: parity of a cheap hash of the id.
                let h = (id.wrapping_mul(2654435761) >> 16) & 1;
                logit += self.slot_weight[s] * (h as f32 * 2.0 - 1.0) * 0.3;
            }
            for d in 0..cfg.dense_dim {
                let x = self.rng.normal() as f32;
                dense.push(x);
                logit += self.dense_weight[d] * x * 0.3;
            }
            let p = 1.0 / (1.0 + (-logit).exp());
            labels.push(if self.rng.f64() < p as f64 { 1.0 } else { 0.0 });
        }
        Batch { size, sparse_ids, dense, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_consistent_shapes() {
        let mut ds = CtrDataset::new(DatasetConfig::default(), 1);
        let b = ds.next_batch(32);
        assert_eq!(b.size, 32);
        assert_eq!(b.sparse_ids.len(), 32 * ds.cfg.slots);
        assert_eq!(b.dense.len(), 32 * ds.cfg.dense_dim);
        assert_eq!(b.labels.len(), 32);
        assert_eq!(b.ids_of(3, ds.cfg.slots).len(), ds.cfg.slots);
    }

    #[test]
    fn ids_stay_in_vocab_and_are_skewed() {
        let mut ds = CtrDataset::new(DatasetConfig::default(), 2);
        let b = ds.next_batch(512);
        let vocab = ds.cfg.vocab as u32;
        assert!(b.sparse_ids.iter().all(|&id| id < vocab));
        // Skew: the head 1% of the vocab should grab far more than 1%.
        let head = b.sparse_ids.iter().filter(|&&id| (id as usize) < ds.cfg.vocab / 100).count();
        assert!(head as f64 > 0.2 * b.sparse_ids.len() as f64, "head={head}");
    }

    #[test]
    fn labels_are_binary_with_sane_rate() {
        let mut ds = CtrDataset::new(DatasetConfig::default(), 3);
        let b = ds.next_batch(4096);
        assert!(b.labels.iter().all(|&l| l == 0.0 || l == 1.0));
        let rate = b.labels.iter().sum::<f32>() / b.size as f32;
        assert!((0.05..0.6).contains(&rate), "rate={rate}");
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a = CtrDataset::new(DatasetConfig::default(), 7);
        let mut b = CtrDataset::new(DatasetConfig::default(), 7);
        let ba = a.next_batch(16);
        let bb = b.next_batch(16);
        assert_eq!(ba.sparse_ids, bb.sparse_ids);
        assert_eq!(ba.labels, bb.labels);
    }

    #[test]
    fn labels_carry_learnable_signal() {
        // The hidden model implies the hash-parity feature correlates with
        // labels; verify the correlation is non-trivial so training can fit.
        let mut ds = CtrDataset::new(DatasetConfig::default(), 11);
        let b = ds.next_batch(8192);
        let slots = ds.cfg.slots;
        let mut cov = 0.0f64;
        let mean_label = b.labels.iter().sum::<f32>() as f64 / b.size as f64;
        for row in 0..b.size {
            let mut feat = 0.0f64;
            for (s, &id) in b.ids_of(row, slots).iter().enumerate() {
                let h = (id.wrapping_mul(2654435761) >> 16) & 1;
                feat += ds.slot_weight[s] as f64 * (h as f64 * 2.0 - 1.0);
            }
            cov += feat * (b.labels[row] as f64 - mean_label);
        }
        assert!(cov.abs() / b.size as f64 > 1e-3, "cov={cov}");
    }
}
