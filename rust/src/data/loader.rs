//! Prefetching batch loader: the §3 data-management path made concrete.
//!
//! "HeterPS prefetches some input training data and caches them in the
//! memory of CPU workers" — a background producer thread generates (or in
//! production: reads) batches ahead of the trainer and stages them in the
//! bounded [`PrefetchCache`]; the trainer consumes in order and never
//! blocks on generation as long as the prefetch depth covers the step
//! time. Backpressure is the cache capacity.

use super::cache::PrefetchCache;
use super::dataset::{Batch, CtrDataset};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Background-prefetching loader over the synthetic CTR stream.
pub struct PrefetchLoader {
    cache: Arc<PrefetchCache<Batch>>,
    stop: Arc<AtomicBool>,
    producer: Option<JoinHandle<()>>,
    next: u64,
}

impl PrefetchLoader {
    /// Start prefetching `batch_size`-row batches with `depth` batches of
    /// lookahead.
    pub fn start(mut dataset: CtrDataset, batch_size: usize, depth: usize) -> Self {
        let cache = Arc::new(PrefetchCache::new(depth.max(1)));
        let stop = Arc::new(AtomicBool::new(false));
        let producer = {
            let cache = cache.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut idx = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let batch = dataset.next_batch(batch_size);
                    // `put` blocks (pinned-full backpressure) only if the
                    // consumer pins; with plain consumption it evicts LRU,
                    // so gate on occupancy to bound generation.
                    while cache.len() >= depth && !stop.load(Ordering::Relaxed) {
                        std::thread::yield_now();
                    }
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    cache.put(idx, batch);
                    cache.set_pinned(idx, true); // never evict ahead-of-reader
                    idx += 1;
                }
            })
        };
        PrefetchLoader { cache, stop, producer: Some(producer), next: 0 }
    }

    /// Next batch, in generation order; spins briefly if the producer is
    /// behind (cold start).
    pub fn next_batch(&mut self) -> Batch {
        loop {
            if let Some(b) = self.cache.take(self.next) {
                self.next += 1;
                return b;
            }
            std::thread::yield_now();
        }
    }

    /// Batches currently staged ahead of the consumer.
    pub fn staged(&self) -> usize {
        self.cache.len()
    }

    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }
}

impl Drop for PrefetchLoader {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::DatasetConfig;

    fn loader(depth: usize) -> PrefetchLoader {
        let ds = CtrDataset::new(
            DatasetConfig { vocab: 1000, slots: 4, dense_dim: 2, ..Default::default() },
            7,
        );
        PrefetchLoader::start(ds, 16, depth)
    }

    #[test]
    fn delivers_batches_in_order_and_matches_direct_generation() {
        let mut l = loader(4);
        let mut direct = CtrDataset::new(
            DatasetConfig { vocab: 1000, slots: 4, dense_dim: 2, ..Default::default() },
            7,
        );
        for _ in 0..10 {
            let a = l.next_batch();
            let b = direct.next_batch(16);
            assert_eq!(a.sparse_ids, b.sparse_ids, "prefetch must not reorder/drop");
            assert_eq!(a.labels, b.labels);
        }
    }

    #[test]
    fn producer_stays_ahead_of_slow_consumer() {
        let mut l = loader(8);
        // Give the producer a head start.
        let _ = l.next_batch();
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(l.staged() >= 4, "prefetch depth unused: {}", l.staged());
    }

    #[test]
    fn shutdown_is_clean_even_when_full() {
        let l = loader(2);
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(l); // must not hang on the blocked producer
    }
}
