//! Prefetching batch cache (§3 data management): "HeterPS prefetches some
//! input training data and caches them in the memory of CPU workers."
//!
//! A bounded LRU keyed by batch index, filled ahead of the consumer by a
//! background prefetch thread, with hit/miss accounting used by the data
//! pipeline benches.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// Bounded LRU cache with pinning; thread-safe.
pub struct PrefetchCache<V> {
    inner: Mutex<Inner<V>>,
    not_full: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    capacity: usize,
}

struct Inner<V> {
    map: HashMap<u64, Entry<V>>,
    /// Logical clock for LRU ordering.
    clock: u64,
}

struct Entry<V> {
    value: V,
    last_used: u64,
    pinned: bool,
}

impl<V: Clone> PrefetchCache<V> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        PrefetchCache {
            inner: Mutex::new(Inner { map: HashMap::new(), clock: 0 }),
            not_full: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            capacity,
        }
    }

    /// Insert, evicting the least-recently-used unpinned entry if full.
    /// Blocks while every resident entry is pinned (backpressure onto the
    /// prefetcher).
    pub fn put(&self, key: u64, value: V) {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.map.len() < self.capacity || inner.map.contains_key(&key) {
                break;
            }
            // Evict LRU unpinned.
            let victim = inner
                .map
                .iter()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    inner.map.remove(&k);
                    break;
                }
                None => {
                    inner = self.not_full.wait(inner).unwrap();
                }
            }
        }
        inner.clock += 1;
        let clock = inner.clock;
        inner.map.insert(key, Entry { value, last_used: clock, pinned: false });
    }

    /// Fetch (and touch) an entry.
    pub fn get(&self, key: u64) -> Option<V> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(&key) {
            Some(e) => {
                e.last_used = clock;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Remove and return an entry (consumption path), unblocking writers.
    pub fn take(&self, key: u64) -> Option<V> {
        let mut inner = self.inner.lock().unwrap();
        let out = inner.map.remove(&key).map(|e| e.value);
        if out.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.not_full.notify_all();
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Pin/unpin an entry (pinned entries survive eviction).
    pub fn set_pinned(&self, key: u64, pinned: bool) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let found = match inner.map.get_mut(&key) {
            Some(e) => {
                e.pinned = pinned;
                true
            }
            None => false,
        };
        if !pinned {
            self.not_full.notify_all();
        }
        found
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_take_roundtrip() {
        let c = PrefetchCache::new(4);
        c.put(1, "a");
        c.put(2, "b");
        assert_eq!(c.get(1), Some("a"));
        assert_eq!(c.take(2), Some("b"));
        assert_eq!(c.take(2), None);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn evicts_lru_when_full() {
        let c = PrefetchCache::new(2);
        c.put(1, 1);
        c.put(2, 2);
        c.get(1); // touch 1, making 2 the LRU
        c.put(3, 3);
        assert_eq!(c.get(2), None, "LRU entry should be evicted");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
    }

    #[test]
    fn pinned_entries_survive_eviction() {
        let c = PrefetchCache::new(2);
        c.put(1, 1);
        assert!(c.set_pinned(1, true));
        c.put(2, 2);
        c.put(3, 3); // must evict 2, not pinned 1
        assert!(c.get(1).is_some());
        assert_eq!(c.get(2), None);
    }

    #[test]
    fn hit_rate_accounts() {
        let c = PrefetchCache::new(2);
        c.put(1, 1);
        c.get(1);
        c.get(9);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn concurrent_producer_consumer() {
        use std::sync::Arc;
        // All entries start pinned, so `put` exerts real backpressure on
        // the producer; the consumer unpins + takes in order, guaranteeing
        // nothing is lost to eviction.
        let c = Arc::new(PrefetchCache::new(8));
        let producer = {
            let c = c.clone();
            std::thread::spawn(move || {
                for i in 0..100u64 {
                    c.put(i, i as i32);
                    c.set_pinned(i, true);
                }
            })
        };
        let consumer = {
            let c = c.clone();
            std::thread::spawn(move || {
                let mut got = 0u64;
                while got < 100 {
                    // `take` removes pinned entries too, so consumption
                    // can't race with eviction.
                    if let Some(v) = c.take(got) {
                        assert_eq!(v, got as i32);
                        got += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                got
            })
        };
        producer.join().unwrap();
        assert_eq!(consumer.join().unwrap(), 100);
    }
}
