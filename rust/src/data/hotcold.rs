//! Hot/cold parameter tiering (§3 data management): "there is a monitor
//! that counts the access frequency of each parameter. If the access
//! frequency is high, the monitor marks the parameters as hot ... and the
//! data management module dynamically adjusts it to the high-speed storage
//! devices ... Otherwise ... puts it to SSDs or normal hard disks."
//!
//! Rows of the (huge) embedding table live either in host memory (hot) or
//! in an on-disk spill file (cold). An exponential-decay access counter
//! drives promotion/demotion; the memory tier is capacity-bounded.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;

/// Tiered storage for fixed-width `f32` rows keyed by id.
pub struct HotColdStore {
    dim: usize,
    /// Hot tier capacity in rows.
    hot_capacity: usize,
    hot: HashMap<u64, HotRow>,
    /// Cold tier: row slots in the spill file.
    cold_index: HashMap<u64, u64>,
    spill: File,
    spill_path: PathBuf,
    next_slot: u64,
    free_slots: Vec<u64>,
    /// Decayed access counter per id.
    heat: HashMap<u64, f64>,
    decay: f64,
    pub promotions: u64,
    pub demotions: u64,
}

struct HotRow {
    data: Vec<f32>,
}

impl HotColdStore {
    /// `dim`: row width; `hot_capacity`: max rows resident in memory;
    /// `decay`: per-touch exponential decay applied to all heat (0.999 ≈
    /// a sliding window of ~1000 touches).
    pub fn new(dir: impl Into<PathBuf>, dim: usize, hot_capacity: usize, decay: f64) -> anyhow::Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        // Process id + per-process counter: two stores sharing a directory
        // must never share a spill file.
        static SPILL_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SPILL_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let spill_path = dir.join(format!("spill-{}-{}.bin", std::process::id(), seq));
        let spill = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&spill_path)?;
        Ok(HotColdStore {
            dim,
            hot_capacity: hot_capacity.max(1),
            hot: HashMap::new(),
            cold_index: HashMap::new(),
            spill,
            spill_path,
            next_slot: 0,
            free_slots: Vec::new(),
            heat: HashMap::new(),
            decay,
            promotions: 0,
            demotions: 0,
        })
    }

    fn touch(&mut self, id: u64) {
        let h = self.heat.entry(id).or_insert(0.0);
        *h = *h * self.decay + 1.0;
    }

    /// Read a row, initializing to `init` if absent. Hot hits are served
    /// from memory; cold hits are read from the spill file and promoted.
    pub fn read(&mut self, id: u64, init: impl Fn() -> Vec<f32>) -> anyhow::Result<Vec<f32>> {
        self.touch(id);
        if let Some(row) = self.hot.get(&id) {
            return Ok(row.data.clone());
        }
        let data = if let Some(&slot) = self.cold_index.get(&id) {
            let mut buf = vec![0u8; self.dim * 4];
            self.spill.seek(SeekFrom::Start(slot * (self.dim as u64) * 4))?;
            self.spill.read_exact(&mut buf)?;
            let mut row = vec![0f32; self.dim];
            for (i, chunk) in buf.chunks_exact(4).enumerate() {
                row[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            // Promote: the row is being accessed.
            self.cold_index.remove(&id);
            self.free_slots.push(slot);
            self.promotions += 1;
            row
        } else {
            let row = init();
            assert_eq!(row.len(), self.dim);
            row
        };
        self.insert_hot(id, data.clone())?;
        Ok(data)
    }

    /// Write a row (post-update); resides hot until demoted.
    pub fn write(&mut self, id: u64, data: Vec<f32>) -> anyhow::Result<()> {
        assert_eq!(data.len(), self.dim);
        self.touch(id);
        if let Some(&slot) = self.cold_index.get(&id) {
            self.cold_index.remove(&id);
            self.free_slots.push(slot);
        }
        self.insert_hot(id, data)
    }

    fn insert_hot(&mut self, id: u64, data: Vec<f32>) -> anyhow::Result<()> {
        self.hot.insert(id, HotRow { data });
        // Demote the coldest rows while over capacity.
        while self.hot.len() > self.hot_capacity {
            let coldest = self
                .hot
                .keys()
                .filter(|k| **k != id)
                .min_by(|a, b| {
                    let ha = self.heat.get(a).copied().unwrap_or(0.0);
                    let hb = self.heat.get(b).copied().unwrap_or(0.0);
                    ha.partial_cmp(&hb).unwrap()
                })
                .copied();
            let Some(victim) = coldest else { break };
            let row = self.hot.remove(&victim).unwrap();
            let slot = self.free_slots.pop().unwrap_or_else(|| {
                let s = self.next_slot;
                self.next_slot += 1;
                s
            });
            let mut buf = Vec::with_capacity(self.dim * 4);
            for v in &row.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            self.spill.seek(SeekFrom::Start(slot * (self.dim as u64) * 4))?;
            self.spill.write_all(&buf)?;
            self.cold_index.insert(victim, slot);
            self.demotions += 1;
        }
        Ok(())
    }

    pub fn hot_rows(&self) -> usize {
        self.hot.len()
    }

    pub fn cold_rows(&self) -> usize {
        self.cold_index.len()
    }

    /// Whether an id currently sits in the hot tier.
    pub fn is_hot(&self, id: u64) -> bool {
        self.hot.contains_key(&id)
    }
}

impl Drop for HotColdStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.spill_path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(capacity: usize) -> HotColdStore {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let unique = NEXT.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("heterps-hc-{}-{unique}", std::process::id()));
        HotColdStore::new(dir, 4, capacity, 0.99).unwrap()
    }

    #[test]
    fn read_initializes_and_roundtrips() {
        let mut s = store(8);
        let row = s.read(42, || vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(row, vec![1.0, 2.0, 3.0, 4.0]);
        s.write(42, vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        assert_eq!(s.read(42, || unreachable!()).unwrap(), vec![5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn demotes_cold_rows_to_disk_and_restores() {
        let mut s = store(2);
        for id in 0..6u64 {
            s.write(id, vec![id as f32; 4]).unwrap();
        }
        assert!(s.hot_rows() <= 2);
        assert!(s.cold_rows() >= 4);
        assert!(s.demotions >= 4);
        // Cold rows read back intact (and get promoted). Which ids were
        // demoted is an implementation detail; pick one that is cold now.
        let cold_id = (0..6u64).find(|id| !s.is_hot(*id)).expect("some id is cold");
        let r = s.read(cold_id, || unreachable!()).unwrap();
        assert_eq!(r, vec![cold_id as f32; 4]);
        assert!(s.promotions >= 1);
    }

    #[test]
    fn frequently_accessed_rows_stay_hot() {
        let mut s = store(2);
        // Make row 0 very hot.
        for _ in 0..50 {
            s.read(0, || vec![0.5; 4]).unwrap();
        }
        // Stream many cold rows through.
        for id in 1..20u64 {
            s.write(id, vec![id as f32; 4]).unwrap();
        }
        assert!(s.is_hot(0), "hot row must not be demoted by cold traffic");
    }

    #[test]
    fn slot_reuse_after_promotion() {
        let mut s = store(1);
        s.write(1, vec![1.0; 4]).unwrap();
        s.write(2, vec![2.0; 4]).unwrap(); // demotes 1
        let _ = s.read(1, || unreachable!()).unwrap(); // promotes 1, demotes 2, frees slot
        s.write(3, vec![3.0; 4]).unwrap(); // demotes 1 again, reusing a slot
        assert_eq!(s.read(2, || unreachable!()).unwrap(), vec![2.0; 4]);
        assert_eq!(s.read(1, || unreachable!()).unwrap(), vec![1.0; 4]);
    }
}
