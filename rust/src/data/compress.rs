//! Communication aggregation + compression (§3 data management): "the data
//! management module dynamically aggregates the data to send to reduce the
//! overhead ... we also exploit data compression during the data
//! communication."
//!
//! Gradients tolerate lossy transport; parameters do not. Three codecs:
//! * `F32` — identity (exact).
//! * `F16` — IEEE half quantization, 2x smaller, ~1e-3 relative error.
//! * `SparseF16` — drop near-zero entries then F16 the survivors: the
//!   right codec for embedding-gradient traffic, which is overwhelmingly
//!   zero outside the touched rows.

/// Compression codec selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    F32,
    F16,
    /// Sparse + f16 with the given zero threshold encoded at compress time.
    SparseF16,
}

impl Codec {
    pub const ALL: [Codec; 3] = [Codec::F32, Codec::F16, Codec::SparseF16];

    pub fn name(self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::F16 => "f16",
            Codec::SparseF16 => "sparsef16",
        }
    }

    /// Parse a CLI/config codec name (`f32`, `f16`, `sparsef16`/`sparse`).
    pub fn parse(name: &str) -> anyhow::Result<Codec> {
        match name.to_ascii_lowercase().as_str() {
            "f32" => Ok(Codec::F32),
            "f16" => Ok(Codec::F16),
            "sparsef16" | "sparse" => Ok(Codec::SparseF16),
            other => anyhow::bail!("unknown codec `{other}` (known: f32, f16, sparsef16)"),
        }
    }
}

const MAGIC_F32: u8 = 0;
const MAGIC_F16: u8 = 1;
const MAGIC_SPARSE: u8 = 2;

/// Decoder sanity cap on claimed element counts (2^28 f32s = 1 GiB): a
/// corrupt length field must fail with an error, not abort on allocation.
const MAX_DECODE_ELEMS: usize = 1 << 28;

/// LEB128-style varint append — the shared wire primitive for sparse
/// codec indices and `comm::msg` id deltas.
pub(crate) fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            break;
        }
        out.push(byte | 0x80);
    }
}

/// Bounds- and overflow-checked varint read from `buf` at `*pos`
/// (advanced past the varint on success).
pub(crate) fn read_varint(buf: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        anyhow::ensure!(*pos < buf.len(), "truncated varint");
        anyhow::ensure!(shift < 64, "varint overflow");
        let byte = buf[*pos];
        *pos += 1;
        v |= ((byte & 0x7f) as u64) << shift;
        shift += 7;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
    }
}

/// f32 -> IEEE 754 half bits (round-to-nearest-even via the bit trick).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32 - 127 + 15;
    let mut mant = bits & 0x7f_ffff;
    if ((bits >> 23) & 0xff) == 0xff {
        // Inf/NaN.
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflow -> 0
        }
        // Subnormal half.
        mant |= 0x80_0000;
        let shift = (14 - exp) as u32;
        let half_mant = mant >> shift;
        // Round to nearest.
        let round_bit = 1u32 << (shift - 1);
        let rounded = if (mant & round_bit) != 0 && (mant & (round_bit - 1) | (half_mant & 1)) != 0 {
            half_mant + 1
        } else {
            half_mant
        };
        return sign | rounded as u16;
    }
    // Normal: round mantissa from 23 to 10 bits.
    let round_bit = 0x1000u32;
    if (mant & round_bit) != 0 && ((mant & (round_bit - 1)) != 0 || (mant & (round_bit << 1)) != 0) {
        mant += round_bit << 1;
        if mant & 0x80_0000 != 0 {
            mant = 0;
            exp += 1;
            if exp >= 0x1f {
                return sign | 0x7c00;
            }
        }
    }
    sign | ((exp as u16) << 10) | ((mant >> 13) as u16)
}

/// IEEE 754 half bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = 127 - 15 - 10;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((e + 10 + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Compress a gradient/parameter vector. The frame is self-describing:
/// `[magic u8][len u64][payload]`.
pub fn compress_f32(data: &[f32], codec: Codec) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 8 + data.len() * 2);
    let push_len = |out: &mut Vec<u8>, n: usize| out.extend_from_slice(&(n as u64).to_le_bytes());
    match codec {
        Codec::F32 => {
            out.push(MAGIC_F32);
            push_len(&mut out, data.len());
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Codec::F16 => {
            out.push(MAGIC_F16);
            push_len(&mut out, data.len());
            for v in data {
                out.extend_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
            }
        }
        Codec::SparseF16 => {
            out.push(MAGIC_SPARSE);
            push_len(&mut out, data.len());
            // Indices as delta-varint, values as f16. NaN is kept despite
            // failing the magnitude test — silently zeroing a NaN gradient
            // would mask divergence instead of propagating it.
            let nz: Vec<(usize, f32)> = data
                .iter()
                .copied()
                .enumerate()
                .filter(|(_, v)| v.abs() > 1e-8 || v.is_nan())
                .collect();
            push_len(&mut out, nz.len());
            let mut prev = 0usize;
            for (i, _) in &nz {
                put_varint(&mut out, (i - prev) as u64);
                prev = *i;
            }
            for (_, v) in &nz {
                out.extend_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
            }
        }
    }
    out
}

/// Decompress a frame produced by [`compress_f32`].
pub fn decompress_f32(frame: &[u8]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(frame.len() >= 9, "truncated frame");
    let magic = frame[0];
    let read_u64 = |b: &[u8]| u64::from_le_bytes(b[..8].try_into().unwrap()) as usize;
    let len = read_u64(&frame[1..9]);
    // Sanity-cap the claimed element count before any size arithmetic or
    // allocation: corrupt headers must error, not overflow `len * 4` or
    // abort allocating terabytes.
    anyhow::ensure!(len <= MAX_DECODE_ELEMS, "frame length {len} over decoder cap");
    let body = &frame[9..];
    match magic {
        MAGIC_F32 => {
            anyhow::ensure!(body.len() == len * 4, "f32 payload size");
            Ok(body.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
        }
        MAGIC_F16 => {
            anyhow::ensure!(body.len() == len * 2, "f16 payload size");
            Ok(body
                .chunks_exact(2)
                .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                .collect())
        }
        MAGIC_SPARSE => {
            anyhow::ensure!(body.len() >= 8, "sparse header");
            let nz = read_u64(&body[..8]);
            let mut pos = 8usize;
            // Every index costs at least one varint byte, so a sane `nz`
            // never exceeds the remaining body.
            anyhow::ensure!(nz <= body.len() - 8, "sparse nz count over body size");
            let mut indices = Vec::with_capacity(nz);
            let mut acc = 0usize;
            for _ in 0..nz {
                let delta = read_varint(body, &mut pos)?;
                acc = acc
                    .checked_add(delta as usize)
                    .ok_or_else(|| anyhow::anyhow!("sparse index overflow"))?;
                indices.push(acc);
            }
            anyhow::ensure!(body.len() - pos == nz * 2, "sparse values size");
            let mut out = vec![0f32; len];
            for (k, idx) in indices.iter().enumerate() {
                anyhow::ensure!(*idx < len, "index out of range");
                let c = &body[pos + 2 * k..pos + 2 * k + 2];
                out[*idx] = f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(out)
        }
        _ => anyhow::bail!("unknown codec magic {magic}"),
    }
}

/// Aggregate many small messages into one frame (the §3 "dynamically
/// aggregates the data to send" path): plain length-prefixed packing.
pub fn aggregate(messages: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(messages.len() as u64).to_le_bytes());
    for m in messages {
        out.extend_from_slice(&(m.len() as u64).to_le_bytes());
        out.extend_from_slice(m);
    }
    out
}

/// Inverse of [`aggregate`].
pub fn disaggregate(frame: &[u8]) -> anyhow::Result<Vec<Vec<u8>>> {
    anyhow::ensure!(frame.len() >= 8, "truncated aggregate");
    let n = u64::from_le_bytes(frame[..8].try_into().unwrap()) as usize;
    let mut pos = 8usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        anyhow::ensure!(frame.len() >= pos + 8, "truncated message header");
        let len = u64::from_le_bytes(frame[pos..pos + 8].try_into().unwrap()) as usize;
        pos += 8;
        anyhow::ensure!(frame.len() >= pos + len, "truncated message body");
        out.push(frame[pos..pos + len].to_vec());
        pos += len;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    #[test]
    fn f16_roundtrip_known_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, 1e-4, -3.14159] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            let err = (back - v).abs() / v.abs().max(1.0);
            assert!(err < 1e-3, "{v} -> {back}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e20)).is_infinite()); // overflow
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-12)), 0.0); // underflow
    }

    #[test]
    fn exact_codec_roundtrips_exactly() {
        let data: Vec<f32> = (0..257).map(|i| (i as f32).sin()).collect();
        let frame = compress_f32(&data, Codec::F32);
        assert_eq!(decompress_f32(&frame).unwrap(), data);
    }

    #[test]
    fn f16_codec_halves_size_with_small_error() {
        let data: Vec<f32> = (0..1024).map(|i| ((i as f32) * 0.01).sin()).collect();
        let frame = compress_f32(&data, Codec::F16);
        assert!(frame.len() < data.len() * 4 / 2 + 16);
        let back = decompress_f32(&frame).unwrap();
        for (a, b) in data.iter().zip(&back) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_codec_wins_on_sparse_gradients() {
        let mut data = vec![0f32; 10_000];
        data[17] = 1.5;
        data[9_000] = -2.25;
        let frame = compress_f32(&data, Codec::SparseF16);
        assert!(frame.len() < 64, "sparse frame should be tiny: {}", frame.len());
        let back = decompress_f32(&frame).unwrap();
        assert_eq!(back.len(), data.len());
        assert!((back[17] - 1.5).abs() < 1e-3);
        assert!((back[9_000] + 2.25).abs() < 1e-2);
        assert!(back.iter().enumerate().all(|(i, &v)| v == 0.0 || i == 17 || i == 9_000));
    }

    #[test]
    fn property_all_codecs_roundtrip_within_tolerance() {
        propcheck::check_result(
            0xC0DEC,
            128,
            |rng: &mut Rng| {
                let n = rng.range(1, 300);
                let sparse = rng.chance(0.5);
                let data: Vec<f32> = (0..n)
                    .map(|_| {
                        if sparse && rng.chance(0.8) {
                            0.0
                        } else {
                            (rng.f32() - 0.5) * 20.0
                        }
                    })
                    .collect();
                data
            },
            |data| {
                for codec in [Codec::F32, Codec::F16, Codec::SparseF16] {
                    let back = decompress_f32(&compress_f32(data, codec))
                        .map_err(|e| e.to_string())?;
                    if back.len() != data.len() {
                        return Err(format!("{codec:?}: length changed"));
                    }
                    let tol = if codec == Codec::F32 { 0.0 } else { 0.02 };
                    for (a, b) in data.iter().zip(&back) {
                        if (a - b).abs() > tol * a.abs().max(1.0) + 1e-3 {
                            return Err(format!("{codec:?}: {a} vs {b}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn codec_names_roundtrip_through_parse() {
        for codec in Codec::ALL {
            assert_eq!(Codec::parse(codec.name()).unwrap(), codec);
        }
        assert_eq!(Codec::parse("SPARSE").unwrap(), Codec::SparseF16);
        assert!(Codec::parse("f64").is_err());
    }

    #[test]
    fn edge_values_respect_each_codec_contract() {
        let data = vec![
            0.0f32,
            -0.0,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            65504.0,   // f16 max normal
            -65504.0,
            70000.0,   // overflows f16 -> +inf
            -70000.0,  // -> -inf
            1e-40,     // f32 subnormal, underflows f16 -> 0
            -1e-40,
            3.0e-5,    // lands in f16's subnormal range
        ];
        // F32 is bit-exact, NaN payload and zero signs included.
        let back = decompress_f32(&compress_f32(&data, Codec::F32)).unwrap();
        assert_eq!(back.len(), data.len());
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // F16 keeps signs of zeros, maps overflow to signed inf, keeps NaN.
        let back = decompress_f32(&compress_f32(&data, Codec::F16)).unwrap();
        assert_eq!(back[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(back[1].to_bits(), (-0.0f32).to_bits());
        assert!(back[2].is_nan());
        assert_eq!(back[3], f32::INFINITY);
        assert_eq!(back[4], f32::NEG_INFINITY);
        assert_eq!(back[5], 65504.0);
        assert_eq!(back[7], f32::INFINITY);
        assert_eq!(back[8], f32::NEG_INFINITY);
        assert_eq!(back[9], 0.0);
        assert!((back[11] - 3.0e-5).abs() < 6e-8, "f16 subnormal: {}", back[11]);
        // SparseF16 drops near-zeros (including -0.0, by design) but must
        // never drop NaN or infinities.
        let back = decompress_f32(&compress_f32(&data, Codec::SparseF16)).unwrap();
        assert_eq!(back.len(), data.len());
        assert_eq!(back[1], 0.0);
        assert!(back[2].is_nan(), "SparseF16 must propagate NaN");
        assert_eq!(back[3], f32::INFINITY);
        assert_eq!(back[4], f32::NEG_INFINITY);
        assert_eq!(back[9], 0.0); // below threshold -> dropped
    }

    #[test]
    fn empty_input_roundtrips_through_every_codec() {
        for codec in Codec::ALL {
            let back = decompress_f32(&compress_f32(&[], codec)).unwrap();
            assert!(back.is_empty(), "{codec:?}");
        }
    }

    #[test]
    fn property_length_is_invariant_and_specials_survive() {
        // decompress(compress(x)).len() == x.len() for ALL codecs on ALL
        // inputs — including NaN payloads, infinities, signed zeros,
        // subnormals and f16-overflowing magnitudes.
        propcheck::check_result(
            0xED6E,
            192,
            |rng: &mut Rng| {
                let n = rng.below(200); // 0 included: empty frames
                (0..n)
                    .map(|_| match rng.below(8) {
                        0 => 0.0f32,
                        1 => -0.0,
                        2 => f32::NAN,
                        3 => {
                            if rng.chance(0.5) {
                                f32::INFINITY
                            } else {
                                f32::NEG_INFINITY
                            }
                        }
                        4 => (rng.f32() - 0.5) * 1e6,  // mostly f16 overflow
                        5 => (rng.f32() - 0.5) * 1e-38, // f32 subnormal-ish
                        6 => (rng.f32() - 0.5) * 2e-4,  // f16 subnormal range
                        _ => (rng.f32() - 0.5) * 20.0,  // ordinary values
                    })
                    .collect::<Vec<f32>>()
            },
            |data| {
                for codec in Codec::ALL {
                    let back = decompress_f32(&compress_f32(data, codec))
                        .map_err(|e| format!("{codec:?}: {e}"))?;
                    if back.len() != data.len() {
                        return Err(format!(
                            "{codec:?}: length {} -> {}",
                            data.len(),
                            back.len()
                        ));
                    }
                    for (i, (&a, &b)) in data.iter().zip(&back).enumerate() {
                        let ok = if a.is_nan() {
                            b.is_nan()
                        } else if a.is_infinite() {
                            a == b
                        } else if codec == Codec::F32 {
                            a.to_bits() == b.to_bits()
                        } else if a.abs() >= 65520.0 {
                            // Beyond the f16 rounding boundary: signed inf.
                            b.is_infinite() && b.is_sign_positive() == a.is_sign_positive()
                        } else if a.abs() > 65504.0 {
                            // The max-normal..boundary gray zone may round
                            // either to 65504 or to inf.
                            b.is_infinite() || b.abs() == 65504.0
                        } else {
                            // Lossy codecs: half-precision relative error
                            // plus the sparse/underflow absolute floor.
                            (a - b).abs() <= a.abs() * 1.5e-3 + 6.2e-5
                        };
                        if !ok {
                            return Err(format!("{codec:?}[{i}]: {a} -> {b}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn aggregate_roundtrips() {
        let msgs = vec![vec![1u8, 2, 3], vec![], vec![9u8; 100]];
        let frame = aggregate(&msgs);
        assert_eq!(disaggregate(&frame).unwrap(), msgs);
    }

    #[test]
    fn decompress_rejects_garbage() {
        assert!(decompress_f32(&[]).is_err());
        assert!(decompress_f32(&[42, 0, 0, 0, 0, 0, 0, 0, 0]).is_err());
        let mut frame = compress_f32(&[1.0, 2.0], Codec::F16);
        frame.truncate(frame.len() - 1);
        assert!(decompress_f32(&frame).is_err());
    }

    #[test]
    fn corrupt_length_fields_error_instead_of_allocating() {
        // A claimed element count of u64::MAX must fail the decoder cap,
        // not abort trying to allocate terabytes.
        let mut frame = vec![MAGIC_SPARSE];
        frame.extend_from_slice(&u64::MAX.to_le_bytes());
        frame.extend_from_slice(&0u64.to_le_bytes());
        assert!(decompress_f32(&frame).is_err());
        // An nz count larger than the remaining body errors up front.
        let mut frame = vec![MAGIC_SPARSE];
        frame.extend_from_slice(&10u64.to_le_bytes());
        frame.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(decompress_f32(&frame).is_err());
        // A varint with endless continuation bits errors (no shift
        // overflow panic): nz = 1, then 11 continuation bytes.
        let mut frame = vec![MAGIC_SPARSE];
        frame.extend_from_slice(&10u64.to_le_bytes());
        frame.extend_from_slice(&1u64.to_le_bytes());
        frame.extend_from_slice(&[0x80; 10]);
        frame.push(0x01);
        assert!(decompress_f32(&frame).is_err());
    }

    #[test]
    fn varint_helpers_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }
}
