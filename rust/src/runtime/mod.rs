//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the rust hot path.
//!
//! Python/JAX runs only at build time (`make artifacts`); this module is
//! the request-path bridge: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO *text* is
//! the interchange format — jax ≥ 0.5 emits protos with 64-bit instruction
//! ids that xla_extension 0.5.1 rejects, while the text parser reassigns
//! ids (see /opt/xla-example/README.md and DESIGN.md).

pub mod policy;

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

/// Directory holding `*.hlo.txt` artifacts; override with `HETERPS_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("HETERPS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        // Resolve relative to the workspace root so examples/benches work
        // from any cwd inside the repo.
        let mut d = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        d.push("artifacts");
        d
    })
}

/// Shared PJRT CPU client + executable cache. Compiling an HLO module is
/// expensive (~10–100 ms); every artifact is compiled once per process.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

// The PJRT client is internally synchronized; executions are guarded by
// the executable-level mutex below.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

static GLOBAL: OnceLock<std::result::Result<Arc<Runtime>, String>> = OnceLock::new();

impl Runtime {
    /// Create a fresh CPU runtime.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Process-wide shared runtime (PJRT clients are heavy; one is enough).
    pub fn global() -> Result<Arc<Runtime>> {
        let r = GLOBAL.get_or_init(|| Runtime::cpu().map(Arc::new).map_err(|e| format!("{e:#}")));
        r.clone().map_err(|e| anyhow::anyhow!(e))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache.lock().unwrap().get(&path) {
            return Ok(e.clone());
        }
        anyhow::ensure!(
            path.exists(),
            "artifact {} not found — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let wrapped = Arc::new(Executable { exe: Mutex::new(exe), path: path.clone() });
        self.cache.lock().unwrap().insert(path, wrapped.clone());
        Ok(wrapped)
    }

    /// Load an artifact by bare name from [`artifacts_dir`], e.g.
    /// `"policy_lstm_fwd"` → `artifacts/policy_lstm_fwd.hlo.txt`.
    pub fn load_named(&self, name: &str) -> Result<Arc<Executable>> {
        self.load(artifacts_dir().join(format!("{name}.hlo.txt")))
    }
}

/// A compiled HLO module. All artifacts are lowered with
/// `return_tuple=True`, so outputs always arrive as a tuple.
pub struct Executable {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    pub path: PathBuf,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with literal inputs; returns the tuple elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe.lock().unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.path.display()))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Execute expecting exactly one output tensor.
    pub fn run1(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let mut out = self.run(inputs)?;
        anyhow::ensure!(
            out.len() == 1,
            "{}: expected 1 output, got {}",
            self.path.display(),
            out.len()
        );
        Ok(out.pop().unwrap())
    }
}

/// Literal constructors/readers for the f32 tensors all artifacts use.
pub mod lit {
    use anyhow::Result;

    pub fn scalar(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    pub fn vec1(v: &[f32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    pub fn mat(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        anyhow::ensure!(v.len() == rows * cols, "matrix data/shape mismatch");
        Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
    }

    pub fn to_f32s(l: &xla::Literal) -> Result<Vec<f32>> {
        Ok(l.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves_under_workspace_by_default() {
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts") || std::env::var("HETERPS_ARTIFACTS").is_ok());
    }

    #[test]
    fn load_missing_artifact_reports_make_hint() {
        let rt = match Runtime::global() {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT on this host; covered by integration tests
        };
        let err = match rt.load("/nonexistent/nope.hlo.txt") {
            Err(e) => e,
            Ok(_) => panic!("load of missing artifact must fail"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    // Full load/execute round-trips live in rust/tests/ (they need
    // `make artifacts` to have produced the HLO files).
}
