//! The HLO-backed scheduling policies: the paper's LSTM (§5.2, Figure 3)
//! and the Elman-RNN baseline (§6.2), authored in JAX (layer-2) with the
//! Pallas LSTM-cell kernel (layer-1), AOT-lowered by `python/compile/aot.py`
//! and executed here through PJRT.
//!
//! Two artifacts per architecture:
//! * `policy_{lstm,rnn}_fwd`  — `(params, features, type_mask) -> probs`
//! * `policy_{lstm,rnn}_step` — `(params, features, layer_mask, type_mask,
//!    actions_onehot, advantage, lr) -> params'` (one REINFORCE ascent
//!    step on the surrogate `advantage * sum_l log P(a_l)`, Eq 15–16).
//!
//! The parameter vector layout is defined by python/compile/model.py; rust
//! only ever treats it as an opaque flat `f32` buffer, initialized here
//! with the same uniform(-0.08, 0.08) scheme the paper's NAS lineage uses.

use super::{lit, Executable, Runtime};
use crate::sched::rl::policy::{FeatureMatrix, Policy, Sample, FEAT_DIM, L_MAX, T_MAX};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// LSTM hidden width (must match python/compile/model.py::HIDDEN).
pub const HIDDEN: usize = 64;

/// Flat parameter count of the LSTM policy.
pub const LSTM_PARAMS: usize =
    FEAT_DIM * 4 * HIDDEN + HIDDEN * 4 * HIDDEN + 4 * HIDDEN + HIDDEN * T_MAX + T_MAX;

/// Flat parameter count of the Elman RNN policy.
pub const RNN_PARAMS: usize =
    FEAT_DIM * HIDDEN + HIDDEN * HIDDEN + HIDDEN + HIDDEN * T_MAX + T_MAX;

/// A policy whose forward pass and REINFORCE step run as compiled HLO.
pub struct HloPolicy {
    label: &'static str,
    fwd: Arc<Executable>,
    step: Arc<Executable>,
    params: Vec<f32>,
}

impl HloPolicy {
    fn load(
        label: &'static str,
        fwd_name: &str,
        step_name: &str,
        n_params: usize,
        rng: &mut Rng,
    ) -> Result<HloPolicy> {
        let rt = Runtime::global()?;
        let fwd = rt.load_named(fwd_name)?;
        let step = rt.load_named(step_name)?;
        let params: Vec<f32> = (0..n_params).map(|_| (rng.f32() * 2.0 - 1.0) * 0.08).collect();
        Ok(HloPolicy { label, fwd, step, params })
    }

    /// The paper's LSTM policy.
    pub fn load_lstm(rng: &mut Rng) -> Result<HloPolicy> {
        Self::load("rl-lstm-hlo", "policy_lstm_fwd", "policy_lstm_step", LSTM_PARAMS, rng)
    }

    /// The RL-RNN baseline policy.
    pub fn load_rnn(rng: &mut Rng) -> Result<HloPolicy> {
        Self::load("rl-rnn-hlo", "policy_rnn_fwd", "policy_rnn_step", RNN_PARAMS, rng)
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    fn type_mask(num_types: usize) -> Vec<f32> {
        let mut m = vec![0.0f32; T_MAX];
        for t in 0..num_types.min(T_MAX) {
            m[t] = 1.0;
        }
        m
    }

    fn layer_mask(num_layers: usize) -> Vec<f32> {
        let mut m = vec![0.0f32; L_MAX];
        for l in 0..num_layers.min(L_MAX) {
            m[l] = 1.0;
        }
        m
    }
}

impl Policy for HloPolicy {
    fn name(&self) -> &str {
        self.label
    }

    fn probs(&mut self, feats: &FeatureMatrix) -> Vec<Vec<f64>> {
        let inputs = [
            lit::vec1(&self.params),
            lit::mat(&feats.data, L_MAX, FEAT_DIM).expect("feature shape"),
            lit::vec1(&Self::type_mask(feats.num_types)),
        ];
        let out = self.fwd.run1(&inputs).expect("policy fwd failed");
        let flat = lit::to_f32s(&out).expect("policy fwd output");
        assert_eq!(flat.len(), L_MAX * T_MAX, "probs shape mismatch");
        (0..feats.num_layers)
            .map(|l| {
                let row = &flat[l * T_MAX..l * T_MAX + feats.num_types];
                // Renormalize defensively (masked softmax in HLO is exact,
                // but f32->f64 conversion can drift at the 1e-7 level).
                let sum: f64 = row.iter().map(|&x| x as f64).sum();
                row.iter().map(|&x| (x as f64 / sum.max(1e-30)).max(1e-12)).collect()
            })
            .collect()
    }

    fn update(&mut self, feats: &FeatureMatrix, samples: &[Sample], lr: f64) {
        let n = samples.len().max(1) as f32;
        let features = lit::mat(&feats.data, L_MAX, FEAT_DIM).expect("feature shape");
        let lmask = lit::vec1(&Self::layer_mask(feats.num_layers));
        let tmask = lit::vec1(&Self::type_mask(feats.num_types));
        for s in samples {
            let mut onehot = vec![0.0f32; L_MAX * T_MAX];
            for (l, &a) in s.actions.iter().enumerate() {
                onehot[l * T_MAX + a] = 1.0;
            }
            let inputs = [
                lit::vec1(&self.params),
                features.clone(),
                lmask.clone(),
                tmask.clone(),
                lit::mat(&onehot, L_MAX, T_MAX).expect("onehot shape"),
                lit::scalar(s.advantage as f32),
                lit::scalar(lr as f32 / n),
            ];
            let out = self.step.run1(&inputs).expect("policy step failed");
            self.params = lit::to_f32s(&out).expect("policy step output");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_layout() {
        // Keep in lock-step with python/compile/model.py.
        assert_eq!(FEAT_DIM, 35);
        assert_eq!(LSTM_PARAMS, 35 * 256 + 64 * 256 + 256 + 64 * 64 + 64);
        assert_eq!(RNN_PARAMS, 35 * 64 + 64 * 64 + 64 + 64 * 64 + 64);
    }

    #[test]
    fn masks_have_expected_shape() {
        let t = HloPolicy::type_mask(3);
        assert_eq!(t.len(), T_MAX);
        assert_eq!(t.iter().sum::<f32>(), 3.0);
        let l = HloPolicy::layer_mask(5);
        assert_eq!(l.len(), L_MAX);
        assert_eq!(l.iter().sum::<f32>(), 5.0);
    }

    // Execution tests (probs sum to one, step ascends log-prob) live in
    // rust/tests/policy_hlo.rs, gated on `make artifacts` having run.
}
