//! Calibration: closing the analytic-vs-measured gap (DESIGN.md
//! §Calibration).
//!
//! The §4.1 cost model is the reward signal for every scheduler, yet its
//! coefficients are derived, not measured — and the measured side of this
//! codebase (the discrete-event [`simulator`](crate::simulator), the comm
//! fabric's wire accounting, the Pallas kernel perf reports) systematically
//! disagrees with it: stragglers and dispatch overheads inflate service
//! times, message coalescing deflates wire bytes, accelerator tiles run
//! below the roofline the flops term assumes. This module closes the loop:
//!
//! * A [`ResidualLedger`] collects `(analytic, measured)` pairs per
//!   [`CostTerm`] and resource type from every measurement source.
//! * [`ResidualLedger::fit`] turns them into per-`(term, type)` scale
//!   corrections — the least-squares optimum in log space (the geometric
//!   mean of the measured/analytic ratios), guarded by the median when
//!   outliers drag the mean so a fitted overlay is never worse than
//!   identity in absolute log-residual. Fully deterministic: no RNG, and
//!   the ledger preserves insertion order.
//! * The resulting [`Calibration`] is an overlay parameter of
//!   [`CostModel`](crate::cost::CostModel): scales multiply the cached
//!   per-layer term seconds at model-build time. The *identity* overlay
//!   multiplies by exactly `1.0` — bit-identical to the uncalibrated
//!   evaluator (IEEE 754 `x * 1.0 == x` for finite `x`), which the
//!   determinism suite asserts for every scheduler family.
//! * Each fit bumps the calibration `epoch`; the eval engine hashes the
//!   overlay (epoch + scale bits) into its context fingerprints, so
//!   memoized evaluations can never serve a stale calibration.
//!
//! The ledger also derives the srtf preemption margin
//! ([`ResidualLedger::derived_margin`]): instead of the historical 1.25
//! constant, the observed spread of measured/analytic service-time ratios
//! bounds how far the analytic remaining-time estimate can undershoot.

use crate::config::{Config, Value};
use crate::resources::ResourcePool;
use crate::util::json::Json;
use crate::util::stats;

/// One FNV-1a round over a 64-bit word (the eval engine's fingerprint
/// primitive, re-stated here so the overlay can hash itself).
#[inline]
fn fnv(h: &mut u64, v: u64) {
    *h ^= v;
    *h = h.wrapping_mul(0x0000_0100_0000_01b3);
}

/// Fitted scales outside this band are treated as fit blow-ups (a handful
/// of degenerate samples, not a real hardware trait) and clamped.
const SCALE_MIN: f64 = 0.05;
const SCALE_MAX: f64 = 20.0;

/// The cost-model term a residual (and its fitted scale) applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostTerm {
    /// The Eq 1 flops term of `OCT` (roofline compute seconds).
    Compute,
    /// The IO/memory-streaming part of `OCT` (data-intensive layers and
    /// the dense activation-streaming share).
    Io,
    /// The Eq 2 communication terms of `ODT` (boundary + weight sync).
    Comm,
}

impl CostTerm {
    pub const COUNT: usize = 3;
    pub const ALL: [CostTerm; CostTerm::COUNT] =
        [CostTerm::Compute, CostTerm::Io, CostTerm::Comm];

    pub fn index(self) -> usize {
        match self {
            CostTerm::Compute => 0,
            CostTerm::Io => 1,
            CostTerm::Comm => 2,
        }
    }

    /// The `[calibration]` config key for this term's scale array.
    pub fn name(self) -> &'static str {
        match self {
            CostTerm::Compute => "compute",
            CostTerm::Io => "io",
            CostTerm::Comm => "comm",
        }
    }
}

/// Where a residual sample was measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    /// Discrete-event replay of a provisioned plan (stage service times).
    Simulator,
    /// The comm fabric's wire accounting (`comm::analytic_comm_check`).
    CommFabric,
    /// Structural Pallas kernel profiles (`python/compile/perf_report.py
    /// --json`): VMEM footprints and MXU utilization per tile.
    KernelProfile,
    /// Online: a cluster job's measured service vs its admission estimate.
    Cluster,
}

impl Source {
    pub fn name(self) -> &'static str {
        match self {
            Source::Simulator => "simulator",
            Source::CommFabric => "comm-fabric",
            Source::KernelProfile => "kernel-profile",
            Source::Cluster => "cluster",
        }
    }
}

/// One `(analytic prediction, measured value)` pair. Units cancel in the
/// fit — only the ratio enters — so seconds (simulator), bytes (comm
/// fabric) and unitless roofline fractions (kernel tiles) can share one
/// ledger.
#[derive(Clone, Copy, Debug)]
pub struct Residual {
    pub term: CostTerm,
    pub type_id: usize,
    pub analytic: f64,
    pub measured: f64,
    pub source: Source,
}

impl Residual {
    /// measured / analytic — above 1.0 the model undershot reality.
    pub fn ratio(&self) -> f64 {
        self.measured / self.analytic
    }
}

/// Per-`(term, resource type)` multiplicative corrections for
/// [`CostModel`](crate::cost::CostModel). Empty scales = the identity
/// overlay (every scale reads as exactly `1.0`).
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    /// Fit generation: bumped on every refit so eval-engine fingerprints
    /// (and with them memoized evaluations) roll over.
    epoch: u64,
    /// Resource-type count the scale table was fitted for.
    num_types: usize,
    /// Term-major scale table: `scales[term.index() * num_types + type]`.
    scales: Vec<f64>,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration::identity()
    }
}

impl Calibration {
    /// The do-nothing overlay: every scale is `1.0`, and applying it is
    /// bit-identical to not calibrating at all.
    pub fn identity() -> Self {
        Calibration { epoch: 0, num_types: 0, scales: Vec::new() }
    }

    /// A fitted overlay. `scales` is term-major
    /// (`CostTerm::COUNT * num_types` entries) and must be finite and
    /// positive throughout.
    pub fn fitted(epoch: u64, num_types: usize, scales: Vec<f64>) -> anyhow::Result<Self> {
        let c = Calibration { epoch, num_types, scales };
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.scales.is_empty() {
            return Ok(());
        }
        anyhow::ensure!(
            self.scales.len() == CostTerm::COUNT * self.num_types,
            "calibration: expected {} scales ({} terms x {} types), got {}",
            CostTerm::COUNT * self.num_types,
            CostTerm::COUNT,
            self.num_types,
            self.scales.len()
        );
        for term in CostTerm::ALL {
            for t in 0..self.num_types {
                let s = self.scales[term.index() * self.num_types + t];
                anyhow::ensure!(
                    s.is_finite() && s > 0.0,
                    "calibration.{}[{t}]: scale must be a finite value > 0 (got {s})",
                    term.name()
                );
            }
        }
        Ok(())
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn num_types(&self) -> usize {
        self.num_types
    }

    /// Whether applying this overlay changes nothing (the determinism
    /// contract's "identity" — scales absent or all exactly `1.0`).
    pub fn is_identity(&self) -> bool {
        self.scales.iter().all(|&s| s == 1.0)
    }

    /// The multiplicative correction for one `(term, type)`. Reads as
    /// `1.0` for the identity overlay and for any type outside the fitted
    /// table (a pool can grow after a fit; unseen types stay analytic).
    #[inline]
    pub fn scale(&self, term: CostTerm, type_id: usize) -> f64 {
        if self.scales.is_empty() || type_id >= self.num_types {
            return 1.0;
        }
        self.scales[term.index() * self.num_types + type_id]
    }

    /// Stable hash of the overlay (epoch + scale bits) — folded into the
    /// eval engine's context fingerprints so cached evaluations roll over
    /// on every refit, even one that reproduces identical scales.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        fnv(&mut h, u64::from_le_bytes(*b"calibfp\0"));
        fnv(&mut h, self.epoch);
        fnv(&mut h, self.scales.len() as u64);
        for s in &self.scales {
            fnv(&mut h, s.to_bits());
        }
        h
    }

    /// Render as a `[calibration]` config section (the `calibrate`
    /// subcommand's output; [`Calibration::from_config`] reads it back
    /// bit-exactly — Rust's shortest-round-trip float formatting).
    pub fn to_config_section(&self) -> String {
        let mut out = String::from("[calibration]\n");
        out.push_str(&format!("epoch = {}\n", self.epoch));
        out.push_str(&format!("types = {}\n", self.num_types));
        if !self.scales.is_empty() {
            for term in CostTerm::ALL {
                let row: Vec<String> =
                    (0..self.num_types).map(|t| format!("{}", self.scale(term, t))).collect();
                out.push_str(&format!("{} = [{}]\n", term.name(), row.join(", ")));
            }
        }
        out
    }

    /// Load a `[calibration]` section. `Ok(None)` when the config has no
    /// such section; an `epoch`/`types` header with no scale arrays is an
    /// explicit identity overlay.
    pub fn from_config(cfg: &Config) -> anyhow::Result<Option<Calibration>> {
        if cfg.keys_under("calibration.").is_empty() {
            return Ok(None);
        }
        let epoch = cfg.usize_or("calibration.epoch", 0) as u64;
        let num_types = cfg.usize_or("calibration.types", 0);
        let mut rows: Vec<Option<Vec<f64>>> = Vec::new();
        for term in CostTerm::ALL {
            let key = format!("calibration.{}", term.name());
            let Some(v) = cfg.get(&key) else {
                rows.push(None);
                continue;
            };
            let arr = match v {
                Value::Array(items) => items,
                _ => anyhow::bail!("{key}: expected an array of scales"),
            };
            let mut parsed = Vec::with_capacity(arr.len());
            for (i, item) in arr.iter().enumerate() {
                let s = item
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("{key}[{i}]: expected a number"))?;
                parsed.push(s);
            }
            rows.push(Some(parsed));
        }
        if rows.iter().all(Option::is_none) {
            // Header-only section: an explicit identity overlay (used by
            // the verify smoke to pin the bit-identity contract).
            return Ok(Some(Calibration { epoch, num_types: 0, scales: Vec::new() }));
        }
        let mut scales = Vec::with_capacity(CostTerm::COUNT * num_types);
        for (term, row) in CostTerm::ALL.iter().zip(&rows) {
            let row = row.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "calibration.{}: missing — a fitted section needs all of {}",
                    term.name(),
                    CostTerm::ALL.map(CostTerm::name).join("/")
                )
            })?;
            anyhow::ensure!(
                row.len() == num_types,
                "calibration.{}: expected {num_types} scales (one per type), got {}",
                term.name(),
                row.len()
            );
            scales.extend_from_slice(row);
        }
        Ok(Some(Calibration::fitted(epoch, num_types, scales)?))
    }
}

/// The `(analytic, measured)` sample store every measurement source feeds.
#[derive(Clone, Debug, Default)]
pub struct ResidualLedger {
    residuals: Vec<Residual>,
}

impl ResidualLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.residuals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.residuals.is_empty()
    }

    pub fn records(&self) -> &[Residual] {
        &self.residuals
    }

    /// Record one pair. Non-finite or non-positive values carry no ratio
    /// information (the fit works in log space) and are dropped; returns
    /// whether the sample was kept.
    pub fn record(
        &mut self,
        term: CostTerm,
        type_id: usize,
        analytic: f64,
        measured: f64,
        source: Source,
    ) -> bool {
        let ok = analytic.is_finite() && analytic > 0.0 && measured.is_finite() && measured > 0.0;
        if ok {
            self.residuals.push(Residual { term, type_id, analytic, measured, source });
        }
        ok
    }

    /// Feed every per-stage `(analytic ET, measured service)` pair of one
    /// simulated run — the compute-side residual source. The simulator's
    /// service times fold jitter and dispatch overheads over the whole Eq 3
    /// stage time, so the samples land on [`CostTerm::Compute`] (the term
    /// that dominates every provisioned stage's ET).
    pub fn record_sim(&mut self, sim: &crate::simulator::SimResult) -> usize {
        let mut kept = 0;
        for s in &sim.stage_samples {
            if self.record(
                CostTerm::Compute,
                s.type_id,
                s.analytic_et,
                s.measured_et,
                Source::Simulator,
            ) {
                kept += 1;
            }
        }
        kept
    }

    /// Feed one comm-fabric cross-check (analytic Eq 2 bytes vs bytes
    /// actually put on the wire; coalescing makes the ratio < 1).
    /// `type_id` is the worker type whose sync traffic was measured.
    pub fn record_comm_check(&mut self, check: &crate::comm::CommCheck, type_id: usize) -> bool {
        self.record(
            CostTerm::Comm,
            type_id,
            check.analytic_bytes,
            check.measured_bytes,
            Source::CommFabric,
        )
    }

    /// Ingest a structural kernel report (`python/compile/perf_report.py
    /// --json`): every Pallas tile with a nonzero MXU utilization `u` says
    /// the roofline flops term undershoots real compute time by `1/u` on
    /// accelerator types. Recorded as `(analytic = 1, measured = 1/u)`
    /// against [`CostTerm::Compute`] for each non-CPU type (the tiles are
    /// accelerator kernels; CPU stages never run them). Returns the number
    /// of samples recorded.
    pub fn ingest_kernel_report(&mut self, report: &Json, pool: &ResourcePool) -> usize {
        let Some(kernels) = report.get("kernels").and_then(Json::as_arr) else {
            return 0;
        };
        let cpu_id = pool.cpu_type().map(|c| c.id);
        let mut kept = 0;
        for k in kernels {
            let Some(util) = k.get("mxu_util").and_then(Json::as_f64) else {
                continue;
            };
            if !(util > 0.0 && util <= 1.0) {
                continue; // memory-bound tiles (util 0) say nothing about flops
            }
            for t in 0..pool.num_types() {
                if Some(t) == cpu_id {
                    continue;
                }
                if self.record(CostTerm::Compute, t, 1.0, 1.0 / util, Source::KernelProfile) {
                    kept += 1;
                }
            }
        }
        kept
    }

    /// Mean absolute log-residual `|ln(measured / analytic)|` over the
    /// ledger — the gap metric the fit shrinks. 0.0 when empty.
    pub fn mean_abs_log_residual(&self) -> f64 {
        self.mean_abs_log_residual_under(&Calibration::identity())
    }

    /// The same metric with an overlay applied:
    /// `|ln(measured / (scale * analytic))|`.
    pub fn mean_abs_log_residual_under(&self, calib: &Calibration) -> f64 {
        if self.residuals.is_empty() {
            return 0.0;
        }
        let logs: Vec<f64> = self
            .residuals
            .iter()
            .map(|r| (r.measured / (calib.scale(r.term, r.type_id) * r.analytic)).ln().abs())
            .collect();
        stats::mean(&logs)
    }

    /// Fit per-`(term, type)` scales: for each group, the log-space
    /// least-squares optimum (geometric mean of the ratios), falling back
    /// to the median log-ratio whenever that gives a smaller absolute
    /// log-residual — the guard that makes a fitted overlay never worse
    /// than identity on the data it was fitted on (the median minimizes
    /// the group's L1 residual; with all-positive log-ratios it beats
    /// zero strictly). Groups with no samples keep scale 1.0.
    /// Deterministic: insertion order, no RNG.
    pub fn fit(&self, num_types: usize, epoch: u64) -> Calibration {
        let mut scales = vec![1.0f64; CostTerm::COUNT * num_types];
        for term in CostTerm::ALL {
            for t in 0..num_types {
                let logs: Vec<f64> = self
                    .residuals
                    .iter()
                    .filter(|r| r.term == term && r.type_id == t)
                    .map(|r| r.ratio().ln())
                    .collect();
                if logs.is_empty() {
                    continue;
                }
                let l1 = |c: f64| logs.iter().map(|r| (r - c).abs()).sum::<f64>();
                let ls = stats::mean(&logs);
                let med = stats::median(&logs);
                let center = if l1(ls) <= l1(med) { ls } else { med };
                scales[term.index() * num_types + t] = center.exp().clamp(SCALE_MIN, SCALE_MAX);
            }
        }
        Calibration { epoch, num_types, scales }
    }

    /// Derive the srtf preemption margin from the observed service-time
    /// residual spread: the p95 of measured/analytic ratios over the
    /// service-time sources ([`Source::Simulator`], [`Source::Cluster`]),
    /// clamped into `[1.0, cap]`. With fewer than [`MARGIN_MIN_SAMPLES`]
    /// samples the spread is not trustworthy and the configured cap (the
    /// operator's knob) stands. The derived margin can only *shrink* the
    /// knob, never raise it — preemption never gets more conservative than
    /// configured.
    pub fn derived_margin(&self, cap: f64) -> f64 {
        let ratios: Vec<f64> = self
            .residuals
            .iter()
            .filter(|r| matches!(r.source, Source::Simulator | Source::Cluster))
            .map(Residual::ratio)
            .collect();
        if ratios.len() < MARGIN_MIN_SAMPLES {
            return cap;
        }
        stats::percentile(&ratios, 95.0).clamp(1.0, cap)
    }
}

/// Service-time samples needed before [`ResidualLedger::derived_margin`]
/// trusts the observed spread over the configured cap.
pub const MARGIN_MIN_SAMPLES: usize = 8;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::paper_testbed;

    #[test]
    fn term_indices_cover_count() {
        for (i, term) in CostTerm::ALL.iter().enumerate() {
            assert_eq!(term.index(), i);
        }
        assert_eq!(CostTerm::COUNT, CostTerm::ALL.len());
    }

    #[test]
    fn identity_scales_are_exactly_one() {
        let id = Calibration::identity();
        assert!(id.is_identity());
        assert_eq!(id.epoch(), 0);
        for term in CostTerm::ALL {
            for t in 0..5 {
                assert_eq!(id.scale(term, t).to_bits(), 1.0f64.to_bits());
            }
        }
    }

    #[test]
    fn ledger_rejects_degenerate_samples() {
        let mut ledger = ResidualLedger::new();
        assert!(!ledger.record(CostTerm::Compute, 0, 0.0, 1.0, Source::Simulator));
        assert!(!ledger.record(CostTerm::Compute, 0, 1.0, -2.0, Source::Simulator));
        assert!(!ledger.record(CostTerm::Compute, 0, f64::NAN, 1.0, Source::Simulator));
        assert!(!ledger.record(CostTerm::Compute, 0, 1.0, f64::INFINITY, Source::Simulator));
        assert!(ledger.is_empty());
        assert!(ledger.record(CostTerm::Compute, 0, 1.0, 1.2, Source::Simulator));
        assert_eq!(ledger.len(), 1);
    }

    #[test]
    fn fit_recovers_a_known_scale() {
        // Every compute sample on type 1 runs exactly 2x the analytic
        // estimate: the fitted scale must be 2, other groups stay 1.
        let mut ledger = ResidualLedger::new();
        for i in 1..=6 {
            let a = i as f64 * 0.01;
            ledger.record(CostTerm::Compute, 1, a, 2.0 * a, Source::Simulator);
        }
        let calib = ledger.fit(2, 1);
        assert!((calib.scale(CostTerm::Compute, 1) - 2.0).abs() < 1e-12);
        assert_eq!(calib.scale(CostTerm::Compute, 0).to_bits(), 1.0f64.to_bits());
        assert_eq!(calib.scale(CostTerm::Io, 1).to_bits(), 1.0f64.to_bits());
        assert_eq!(calib.scale(CostTerm::Comm, 1).to_bits(), 1.0f64.to_bits());
        assert_eq!(calib.epoch(), 1);
        assert!(!calib.is_identity());
        calib.validate().unwrap();
    }

    #[test]
    fn fit_never_increases_abs_log_residual() {
        // Mixed, skewed ratios across terms and types: the fitted overlay
        // must shrink the mean absolute log-residual (the median guard
        // makes this a guarantee, not a tendency).
        let mut ledger = ResidualLedger::new();
        let ratios = [1.05, 1.08, 1.1, 1.35, 2.4];
        for (i, &r) in ratios.iter().enumerate() {
            let a = 0.5 + i as f64 * 0.1;
            ledger.record(CostTerm::Compute, 0, a, r * a, Source::Simulator);
            ledger.record(CostTerm::Comm, 1, a, 0.8 * a, Source::CommFabric);
        }
        let before = ledger.mean_abs_log_residual();
        let calib = ledger.fit(2, 1);
        let after = ledger.mean_abs_log_residual_under(&calib);
        assert!(after < before, "residual did not shrink: {after} !< {before}");
    }

    #[test]
    fn fit_clamps_blowups() {
        let mut ledger = ResidualLedger::new();
        ledger.record(CostTerm::Io, 0, 1e-9, 1.0, Source::Simulator); // ratio 1e9
        let calib = ledger.fit(1, 1);
        assert!((calib.scale(CostTerm::Io, 0) - SCALE_MAX).abs() < 1e-12);
    }

    #[test]
    fn config_roundtrip_is_bit_exact() {
        let mut ledger = ResidualLedger::new();
        for i in 1..=5 {
            let a = i as f64;
            ledger.record(CostTerm::Compute, 0, a, 1.17 * a, Source::Simulator);
            ledger.record(CostTerm::Compute, 1, a, 1.03 * a, Source::Simulator);
            ledger.record(CostTerm::Comm, 1, a, 0.77 * a, Source::CommFabric);
        }
        let calib = ledger.fit(2, 3);
        let text = calib.to_config_section();
        let cfg = Config::parse(&text).unwrap();
        let back = Calibration::from_config(&cfg).unwrap().unwrap();
        assert_eq!(back.epoch(), calib.epoch());
        assert_eq!(back.num_types(), calib.num_types());
        for term in CostTerm::ALL {
            for t in 0..2 {
                assert_eq!(
                    back.scale(term, t).to_bits(),
                    calib.scale(term, t).to_bits(),
                    "{}[{t}]",
                    term.name()
                );
            }
        }
        assert_eq!(back.fingerprint(), calib.fingerprint());
    }

    #[test]
    fn header_only_section_is_explicit_identity() {
        let cfg = Config::parse("[calibration]\nepoch = 0\n").unwrap();
        let calib = Calibration::from_config(&cfg).unwrap().unwrap();
        assert!(calib.is_identity());
        assert_eq!(calib.fingerprint(), Calibration::identity().fingerprint());
        // No section at all: None, so callers fall back to the default.
        let empty = Config::parse("[cost]\nbatch_size = 64\n").unwrap();
        assert!(Calibration::from_config(&empty).unwrap().is_none());
    }

    #[test]
    fn from_config_rejects_malformed_sections() {
        // Wrong arity.
        let cfg =
            Config::parse("[calibration]\nepoch = 1\ntypes = 2\ncompute = [1.0]\nio = [1, 1]\ncomm = [1, 1]\n")
                .unwrap();
        assert!(Calibration::from_config(&cfg).unwrap_err().to_string().contains("compute"));
        // Missing one term's array.
        let cfg = Config::parse("[calibration]\nepoch = 1\ntypes = 1\ncompute = [1.1]\n").unwrap();
        assert!(Calibration::from_config(&cfg).is_err());
        // Non-positive scale.
        let cfg = Config::parse(
            "[calibration]\nepoch = 1\ntypes = 1\ncompute = [0.0]\nio = [1]\ncomm = [1]\n",
        )
        .unwrap();
        let err = Calibration::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("calibration.compute[0]"), "{err}");
    }

    #[test]
    fn fingerprint_separates_epochs_and_scales() {
        let a = Calibration::fitted(1, 1, vec![1.0, 1.0, 1.0]).unwrap();
        let b = Calibration::fitted(2, 1, vec![1.0, 1.0, 1.0]).unwrap();
        // Same scales, different epoch: a refit must still roll caches.
        assert_ne!(a.fingerprint(), b.fingerprint());
        let c = Calibration::fitted(1, 1, vec![1.1, 1.0, 1.0]).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn derived_margin_needs_samples_and_clamps() {
        let mut ledger = ResidualLedger::new();
        // Too few samples: the configured cap stands.
        ledger.record(CostTerm::Compute, 0, 1.0, 1.1, Source::Simulator);
        assert_eq!(ledger.derived_margin(1.25), 1.25);
        // Enough samples with a tight spread: margin shrinks below cap.
        for i in 0..10 {
            let m = 1.04 + 0.005 * i as f64;
            ledger.record(CostTerm::Compute, 0, 1.0, m, Source::Simulator);
        }
        let margin = ledger.derived_margin(1.25);
        assert!(margin < 1.25, "margin {margin}");
        assert!(margin >= 1.0);
        // Comm-fabric ratios (coalescing, < 1) must not drag the margin
        // below 1 — they are not service-time evidence.
        for _ in 0..20 {
            ledger.record(CostTerm::Comm, 0, 1.0, 0.6, Source::CommFabric);
        }
        assert!(ledger.derived_margin(1.25) >= 1.0);
    }

    #[test]
    fn kernel_report_ingestion_skips_cpu_and_memory_bound_tiles() {
        let pool = paper_testbed();
        let report = Json::parse(
            r#"{"kernels": [
                {"label": "embedding_bag", "vmem_bytes": 1024, "mxu_util": 0.0},
                {"label": "lstm_cell", "vmem_bytes": 2048, "mxu_util": 0.25},
                {"label": "matmul", "vmem_bytes": 4096, "mxu_util": 1.0}
            ]}"#,
        )
        .unwrap();
        let mut ledger = ResidualLedger::new();
        let kept = ledger.ingest_kernel_report(&report, &pool);
        // 2 usable tiles x every non-CPU type in the testbed.
        let non_cpu = pool.num_types() - 1;
        assert_eq!(kept, 2 * non_cpu);
        assert!(ledger.records().iter().all(|r| {
            r.source == Source::KernelProfile
                && r.term == CostTerm::Compute
                && Some(r.type_id) != pool.cpu_type().map(|c| c.id)
        }));
        // A report with no kernels key is a no-op.
        let empty = Json::parse(r#"{"rows": []}"#).unwrap();
        assert_eq!(ledger.ingest_kernel_report(&empty, &pool), 0);
    }
}
