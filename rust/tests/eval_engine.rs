//! Integration tests for the shared evaluation engine (DESIGN.md
//! §Eval-Engine): the cross-method thread-count determinism suite, the
//! incremental-vs-full bit-equality property, cache/budget semantics and
//! the between-chunk deadline gate.

use heterps::cost::{CostConfig, CostModel};
use heterps::model::zoo;
use heterps::plan::SchedulingPlan;
use heterps::resources::{paper_testbed, simulated_types};
use heterps::sched::{self, registry, Budget, EvalCache, EvalEngine, SchedulerSpec};
use heterps::util::propcheck;
use std::time::Duration;

/// The acceptance bar of the engine: for seeds {1, 42} on `ctrdnn` +
/// `paper_testbed`, every registered method driven under a 200-evaluation
/// budget produces a bit-identical outcome — plan, cost, charged
/// evaluations and cache hits — at 1 and 8 eval threads. Parallelism may
/// only change wall-clock, never what the search does.
#[test]
fn every_method_is_bit_identical_across_eval_thread_counts() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for seed in [1u64, 42] {
        for info in registry() {
            let spec = SchedulerSpec::parse(info.canonical).unwrap();
            let run = |threads: usize| {
                let scheduler = spec.build(seed);
                let engine = EvalEngine::new(&cm).with_threads(threads);
                let mut session = scheduler.session_engine(engine, Budget::evals(200));
                sched::drive(session.as_mut(), None).unwrap_or_else(|e| {
                    panic!("{} seed {seed} t={threads}: {e}", info.canonical)
                })
            };
            let serial = run(1);
            let parallel = run(8);
            assert_eq!(
                serial.plan, parallel.plan,
                "{} seed {seed}: plan differs across thread counts",
                info.canonical
            );
            assert_eq!(
                serial.eval.cost_usd.to_bits(),
                parallel.eval.cost_usd.to_bits(),
                "{} seed {seed}: cost differs across thread counts",
                info.canonical
            );
            assert_eq!(
                serial.eval.provisioning, parallel.eval.provisioning,
                "{} seed {seed}: provisioning differs",
                info.canonical
            );
            assert_eq!(
                (serial.evaluations, serial.cache_hits),
                (parallel.evaluations, parallel.cache_hits),
                "{} seed {seed}: evaluation accounting differs",
                info.canonical
            );
        }
    }
}

/// Incremental delta-evaluation must match the full evaluator bit-for-bit
/// across random plans and random 1–3 gene mutations: the reused profiles
/// are pure functions of their spans, so no drift is tolerable.
#[test]
fn prop_incremental_delta_matches_full_evaluation() {
    let model = zoo::matchnet();
    let pool = simulated_types(4, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let nl = model.num_layers();
    propcheck::check_result(
        0xDE17A,
        128,
        |rng| {
            let base: Vec<usize> = (0..nl).map(|_| rng.below(4)).collect();
            let mut mutated = base.clone();
            for _ in 0..1 + rng.below(3) {
                let pos = rng.below(nl);
                mutated[pos] = rng.below(4);
            }
            (base, mutated)
        },
        |(base, mutated)| {
            let base_plan = SchedulingPlan::new(base.clone());
            let mutated_plan = SchedulingPlan::new(mutated.clone());
            let stages = base_plan.stages();
            let profs = cm.stage_profiles(&stages);
            let full = cm.evaluate(&mutated_plan);
            let delta = cm.evaluate_delta(&mutated_plan, &stages, &profs);
            if full.cost_usd.to_bits() != delta.cost_usd.to_bits() {
                return Err(format!(
                    "cost diverged: full {} vs delta {}",
                    full.cost_usd, delta.cost_usd
                ));
            }
            if full.throughput.to_bits() != delta.throughput.to_bits() {
                return Err("throughput diverged".into());
            }
            if full.train_time_secs.to_bits() != delta.train_time_secs.to_bits() {
                return Err("train time diverged".into());
            }
            if full.feasible != delta.feasible || full.provisioning != delta.provisioning {
                return Err("provisioning diverged".into());
            }
            Ok(())
        },
    );
}

/// A shared cache spans sessions: what one session evaluated, a later
/// session over an equal context gets as uncharged hits. This is the
/// elastic-controller / cluster-admission reuse path.
#[test]
fn shared_cache_makes_a_rerun_nearly_free() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let spec = SchedulerSpec::parse("greedy").unwrap();
    let cache = EvalCache::new();

    let first = {
        let scheduler = spec.build(7);
        let engine = EvalEngine::new(&cm).with_cache(cache.clone());
        let mut session = scheduler.session_engine(engine, Budget::unlimited());
        sched::drive(session.as_mut(), None).unwrap()
    };
    assert!(first.evaluations > 0);
    assert_eq!(first.cache_hits, 0, "greedy never revisits a plan on ctrdnn");

    // Greedy is deterministic: the rerun replays the identical plan
    // sequence, so every evaluation is served from the shared cache.
    let second = {
        let scheduler = spec.build(7);
        let engine = EvalEngine::new(&cm).with_cache(cache.clone());
        let mut session = scheduler.session_engine(engine, Budget::unlimited());
        sched::drive(session.as_mut(), None).unwrap()
    };
    assert_eq!(second.plan, first.plan);
    assert_eq!(second.evaluations, 0, "rerun must be fully cached");
    assert_eq!(second.cache_hits, first.evaluations);
    assert_eq!(cache.stats().charged, first.evaluations as u64);

    // A different floor is a different context: no cross-contamination.
    let tighter = CostConfig {
        throughput_limit: CostConfig::default().throughput_limit * 2.0,
        ..CostConfig::default()
    };
    let cm_tight = CostModel::new(&model, &pool, tighter);
    let third = {
        let scheduler = spec.build(7);
        let engine = EvalEngine::new(&cm_tight).with_cache(cache.clone());
        let mut session = scheduler.session_engine(engine, Budget::unlimited());
        sched::drive(session.as_mut(), None).unwrap()
    };
    assert!(third.evaluations > 0, "a new floor must not reuse stale evaluations");
}

/// Cache hits are not charged against the evaluation budget, so a
/// warm-started session whose candidates were already scored keeps its
/// whole budget for fresh plans.
#[test]
fn cache_hits_do_not_consume_the_budget() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let cache = EvalCache::new();
    let warm_plan = SchedulingPlan::new(
        model.layers.iter().map(|l| if l.kind.data_intensive() { 0 } else { 1 }).collect(),
    );
    // Pre-score the warm plan through an engine on the shared cache.
    EvalEngine::new(&cm).with_cache(cache.clone()).evaluate(&warm_plan);

    let spec = SchedulerSpec::parse("genetic").unwrap();
    let scheduler = spec.build(11);
    let engine = EvalEngine::new(&cm).with_cache(cache.clone());
    let mut session = scheduler.session_engine(engine, Budget::evals(1));
    session.warm_start(&warm_plan); // hit: budget still untouched
    let out = sched::drive(session.as_mut(), None).unwrap();
    assert!(out.cache_hits >= 1);
    assert_eq!(out.evaluations, 1, "the single budgeted evaluation goes to a fresh plan");
}

/// The deadline gate fires between batch chunks too: an already-expired
/// deadline stops a parallel batched session before any evaluation, just
/// like the serial path.
#[test]
fn expired_deadline_stops_parallel_batches_before_any_work() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for spec_str in ["genetic", "bf", "rl-tabular"] {
        let scheduler = SchedulerSpec::parse(spec_str).unwrap().build(3);
        let engine = EvalEngine::new(&cm).with_threads(8);
        let mut session = scheduler
            .session_engine(engine, Budget::unlimited().with_deadline(Duration::ZERO));
        let result = sched::drive(session.as_mut(), None);
        assert!(result.is_err(), "{spec_str}: expired deadline must yield no plans");
        assert_eq!(session.evaluations(), 0, "{spec_str}");
        assert!(session.report().budget_exhausted, "{spec_str}");
    }
}

/// `schedule()` still equals a manually driven parallel session for a
/// stochastic method — the engine default path and the explicit path
/// share one deterministic contract.
#[test]
fn parallel_session_reproduces_schedule_for_stochastic_methods() {
    let model = zoo::ctrdnn();
    let pool = simulated_types(4, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for spec_str in ["rl-tabular:rounds=15", "genetic:gens=6", "bo:iters=10"] {
        let spec = SchedulerSpec::parse(spec_str).unwrap();
        let one_shot = spec.build(42).schedule(&cm);
        let scheduler = spec.build(42);
        let engine = EvalEngine::new(&cm).with_threads(4);
        let mut session = scheduler.session_engine(engine, Budget::unlimited());
        let stepped = sched::drive(session.as_mut(), None).unwrap();
        assert_eq!(stepped.plan, one_shot.plan, "{spec_str}");
        assert_eq!(stepped.evaluations, one_shot.evaluations, "{spec_str}");
        assert_eq!(stepped.cache_hits, one_shot.cache_hits, "{spec_str}");
        assert_eq!(
            stepped.eval.cost_usd.to_bits(),
            one_shot.eval.cost_usd.to_bits(),
            "{spec_str}"
        );
    }
}
