//! Integration tests for the calibration loop (DESIGN.md §Calibration):
//! the identity overlay's bit-identity contract across every scheduler
//! family, calibration-epoch cache invalidation on a shared `EvalCache`,
//! and the residual-shrinks property of the fit.

use heterps::calib::Calibration;
use heterps::calib::ResidualLedger;
use heterps::cost::{CostConfig, CostModel};
use heterps::model::zoo;
use heterps::plan::SchedulingPlan;
use heterps::resources::{paper_testbed, simulated_types};
use heterps::sched::{self, registry, Budget, EvalCache, EvalEngine, SchedulerSpec};
use heterps::simulator::{simulate_plan, SimConfig};
use heterps::util::propcheck;

/// The determinism contract of the overlay: the *identity* calibration
/// multiplies every cached term by exactly 1.0, so for seeds {1, 42} and
/// every registered scheduler family the outcome — plan, cost bits,
/// charged evaluations, cache hits — must be bit-identical to the
/// uncalibrated evaluator.
#[test]
fn identity_calibration_is_bit_identical_for_every_scheduler_family() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let plain = CostModel::new(&model, &pool, CostConfig::default());
    let overlaid = CostModel::with_calibration(
        &model,
        &pool,
        CostConfig::default(),
        Calibration::identity(),
    );
    for seed in [1u64, 42] {
        for info in registry() {
            let spec = SchedulerSpec::parse(info.canonical).unwrap();
            let run = |cm: &CostModel| {
                let scheduler = spec.build(seed);
                let engine = EvalEngine::new(cm);
                let mut session = scheduler.session_engine(engine, Budget::evals(150));
                sched::drive(session.as_mut(), None)
                    .unwrap_or_else(|e| panic!("{} seed {seed}: {e}", info.canonical))
            };
            let a = run(&plain);
            let b = run(&overlaid);
            assert_eq!(a.plan, b.plan, "{} seed {seed}: plan differs", info.canonical);
            assert_eq!(
                a.eval.cost_usd.to_bits(),
                b.eval.cost_usd.to_bits(),
                "{} seed {seed}: cost differs under the identity overlay",
                info.canonical
            );
            assert_eq!(
                a.eval.throughput.to_bits(),
                b.eval.throughput.to_bits(),
                "{} seed {seed}: throughput differs",
                info.canonical
            );
            assert_eq!(
                (a.evaluations, a.cache_hits),
                (b.evaluations, b.cache_hits),
                "{} seed {seed}: evaluation accounting differs",
                info.canonical
            );
        }
    }
}

/// A refit bumps the calibration epoch, and the epoch is hashed into the
/// engine's context fingerprint — so a shared cache can never serve an
/// evaluation scored under a stale overlay, even when the scales are
/// numerically unchanged.
#[test]
fn calibration_epoch_rolls_the_shared_cache() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let nt = pool.num_types();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let spec = SchedulerSpec::parse("greedy").unwrap();
    let cache = EvalCache::new();

    let run = |cm: &CostModel| {
        let scheduler = spec.build(7);
        let engine = EvalEngine::new(cm).with_cache(cache.clone());
        let mut session = scheduler.session_engine(engine, Budget::unlimited());
        sched::drive(session.as_mut(), None).unwrap()
    };
    let first = run(&cm);
    assert!(first.evaluations > 0);

    // Same model, same config, identity overlay: fully cached.
    let replay = run(&CostModel::with_calibration(
        &model,
        &pool,
        CostConfig::default(),
        Calibration::identity(),
    ));
    assert_eq!(replay.evaluations, 0, "identity overlay must reuse the shared cache");
    assert_eq!(replay.cache_hits, first.evaluations);

    // Epoch 1 with all-1.0 scales evaluates to the same numbers, but it
    // is a *different* calibration — the fingerprint must miss.
    let bumped = Calibration::fitted(1, nt, vec![1.0; 3 * nt]).unwrap();
    let refit =
        run(&CostModel::with_calibration(&model, &pool, CostConfig::default(), bumped));
    assert_eq!(
        refit.evaluations, first.evaluations,
        "a bumped epoch must re-evaluate instead of serving stale cache entries"
    );
    assert_eq!(refit.plan, first.plan, "all-1.0 scales change nothing numerically");
}

/// The fit property: on any batch of simulator measurements, the fitted
/// overlay's mean absolute log-residual is never worse than identity —
/// and with the default simulator's systematic overheads (every measured
/// stage time exceeds its analytic estimate) it is strictly better.
#[test]
fn prop_fitted_overlay_shrinks_the_residual() {
    let model = zoo::matchnet();
    let pool = simulated_types(4, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let nl = model.num_layers();
    let simcfg = SimConfig::default();
    propcheck::check_result(
        0xCA11B,
        32,
        |rng| {
            let genes: Vec<usize> = (0..nl).map(|_| rng.below(4)).collect();
            let sim_seed = rng.below(1 << 20) as u64;
            (genes, sim_seed)
        },
        |(genes, sim_seed)| {
            let plan = SchedulingPlan::new(genes.clone());
            let mut ledger = ResidualLedger::new();
            for s in 0..3u64 {
                if let Some(sim) = simulate_plan(&cm, &plan, &simcfg, sim_seed ^ (s << 40)) {
                    ledger.record_sim(&sim);
                }
            }
            if ledger.is_empty() {
                return Ok(()); // not provisionable on this pool — nothing to fit
            }
            let before = ledger.mean_abs_log_residual();
            let calib = ledger.fit(pool.num_types(), 1);
            let after = ledger.mean_abs_log_residual_under(&calib);
            if after > before + 1e-12 {
                return Err(format!("fit worsened the residual: {before} -> {after}"));
            }
            // Default SimConfig folds dispatch/jitter overheads into every
            // stage, so the uncalibrated residual is bounded away from 0
            // and the fit must strictly improve on it.
            if before > 1e-9 && after >= before {
                return Err(format!("fit failed to shrink a real residual: {before}"));
            }
            Ok(())
        },
    );
}
