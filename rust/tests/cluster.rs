//! Integration tests for the multi-tenant cluster scheduler: the
//! determinism contract, sub-pool conservation, the no-stranded-replica
//! invariant under preemption, and policy equivalence on a lone job.

use heterps::cluster::{
    self, mix_by_name, policy_by_name, tight_mix, tight_pool, uniform_mix, ClusterConfig,
    ClusterReport, EventKind,
};
use heterps::resources::{paper_testbed, simulated_types, ResourcePool};
use heterps::sched::SchedulerSpec;

fn cfg(spec: &str, budget: usize) -> ClusterConfig {
    ClusterConfig {
        spec: SchedulerSpec::parse(spec).unwrap(),
        admit_budget_evals: budget,
        ..Default::default()
    }
}

/// Bit-level equality of everything numeric a report carries.
fn assert_reports_bit_identical(a: &ClusterReport, b: &ClusterReport, ctx: &str) {
    assert_eq!(a.jobs.len(), b.jobs.len(), "{ctx}: job count");
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        let id = x.id;
        assert_eq!(
            x.completion_secs.map(f64::to_bits),
            y.completion_secs.map(f64::to_bits),
            "{ctx}: completion of job {id}"
        );
        assert_eq!(
            x.first_start_secs.map(f64::to_bits),
            y.first_start_secs.map(f64::to_bits),
            "{ctx}: start of job {id}"
        );
        assert_eq!(
            x.queueing_delay_secs.to_bits(),
            y.queueing_delay_secs.to_bits(),
            "{ctx}: queueing of job {id}"
        );
        assert_eq!(
            x.sla_violation_secs.to_bits(),
            y.sla_violation_secs.to_bits(),
            "{ctx}: violation of job {id}"
        );
        assert_eq!(x.cost_usd.to_bits(), y.cost_usd.to_bits(), "{ctx}: cost of job {id}");
        assert_eq!(
            (x.rejected, x.preemptions, x.admissions, x.evaluations),
            (y.rejected, y.preemptions, y.admissions, y.evaluations),
            "{ctx}: counters of job {id}"
        );
    }
    assert_eq!(a.makespan_secs.to_bits(), b.makespan_secs.to_bits(), "{ctx}: makespan");
    assert_eq!(
        a.cumulative_cost_usd.to_bits(),
        b.cumulative_cost_usd.to_bits(),
        "{ctx}: cluster cost"
    );
    assert_eq!(a.total_evaluations, b.total_evaluations, "{ctx}: evaluations");
    assert_eq!(a.peak_units, b.peak_units, "{ctx}: peak units");
    assert_eq!(a.util_deciles, b.util_deciles, "{ctx}: utilization histogram");
    assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
    for (x, y) in a.timeline.iter().zip(&b.timeline) {
        assert_eq!(x.at_secs.to_bits(), y.at_secs.to_bits(), "{ctx}: event time");
        assert_eq!((x.job_id, x.kind), (y.job_id, y.kind), "{ctx}: event identity");
        assert_eq!(x.units, y.units, "{ctx}: event units");
    }
}

#[test]
fn cluster_runs_are_bit_deterministic_per_config_and_seed() {
    // The CLI contract: a 6-job mix under every policy replays
    // bit-identically for the same (pool, mix, config, seed) — including
    // the stochastic per-job searches and the straggler measurements.
    let pool = simulated_types(2, true);
    // One deterministic and one stochastic per-job method: seed-stream
    // bugs in a sampler (ignoring the per-(job, attempt) seed, global
    // RNG state) would only show up under the stochastic one.
    for (mix, seed, method) in [
        ("uniform", 42u64, "greedy"),
        ("uniform", 42u64, "rl-tabular:rounds=10"),
        ("tight", 7u64, "greedy"),
    ] {
        let pool = if mix == "tight" { tight_pool() } else { pool.clone() };
        let queue = mix_by_name(mix, 6, seed, 20_000.0).unwrap();
        let c = cfg(method, 64);
        for name in cluster::policy_names() {
            let p1 = policy_by_name(name, &pool).unwrap();
            let a = cluster::run_cluster(&pool, &queue, p1.as_ref(), &c, seed).unwrap();
            let p2 = policy_by_name(name, &pool).unwrap();
            let b = cluster::run_cluster(&pool, &queue, p2.as_ref(), &c, seed).unwrap();
            assert_reports_bit_identical(&a, &b, &format!("{mix}/{method}/{name}"));
        }
    }
}

#[test]
fn distinct_seeds_perturb_the_outcome() {
    let pool = simulated_types(2, true);
    let c = cfg("greedy", 64);
    let policy = policy_by_name("drf-cost", &pool).unwrap();
    let qa = uniform_mix(5, 1, 20_000.0);
    let qb = uniform_mix(5, 2, 20_000.0);
    let a = cluster::run_cluster(&pool, &qa, policy.as_ref(), &c, 1).unwrap();
    let b = cluster::run_cluster(&pool, &qb, policy.as_ref(), &c, 2).unwrap();
    assert_ne!(a.makespan_secs.to_bits(), b.makespan_secs.to_bits());
}

/// Replay a report's unit ledger: every `Admit` acquires its whole
/// sub-pool (a job can hold at most one), every `Preempt`/`Complete`
/// releases exactly what the job held, and the running total never
/// exceeds the parent pool's per-type limits.
fn check_ledger(report: &ClusterReport, pool: &ResourcePool, ctx: &str) {
    let nt = pool.num_types();
    let mut held: Vec<Option<Vec<usize>>> = vec![None; report.jobs.len()];
    let mut total = vec![0usize; nt];
    for ev in &report.timeline {
        match ev.kind {
            EventKind::Arrive | EventKind::Reject => {
                assert!(ev.units.is_empty(), "{ctx}: {:?} carries units", ev.kind);
            }
            EventKind::Admit => {
                assert!(
                    held[ev.job_id].is_none(),
                    "{ctx}: job {} admitted while already holding a sub-pool",
                    ev.job_id
                );
                assert_eq!(ev.units.len(), nt, "{ctx}: unit arity");
                for (t, &u) in ev.units.iter().enumerate() {
                    total[t] += u;
                    assert!(
                        total[t] <= pool.get(t).max_units,
                        "{ctx}: type {t} holds {} units over limit {} after admitting job {}",
                        total[t],
                        pool.get(t).max_units,
                        ev.job_id
                    );
                }
                held[ev.job_id] = Some(ev.units.clone());
            }
            EventKind::Preempt | EventKind::Complete => {
                let h = held[ev.job_id].take().unwrap_or_else(|| {
                    panic!("{ctx}: job {} released units it never held", ev.job_id)
                });
                assert_eq!(
                    h, ev.units,
                    "{ctx}: job {} released a sub-pool it did not acquire (stranded replicas)",
                    ev.job_id
                );
                for (t, &u) in ev.units.iter().enumerate() {
                    total[t] -= u;
                }
            }
        }
    }
    for (jid, h) in held.iter().enumerate() {
        assert!(h.is_none(), "{ctx}: job {jid} still holds a sub-pool at the end of the run");
    }
    assert!(total.iter().all(|&u| u == 0), "{ctx}: units leaked");
    for (t, &peak) in report.peak_units.iter().enumerate() {
        assert!(peak <= pool.get(t).max_units, "{ctx}: reported peak over limit for type {t}");
    }
}

#[test]
fn conservation_and_no_stranded_replicas_under_preemption() {
    // The tight mix under srtf is the preemption-heavy path: the heavy
    // job preempts medium, and the shorts can preempt heavy in turn. The
    // ledger must balance exactly through every handoff.
    let pool = tight_pool();
    let queue = tight_mix(6, 42, 20_000.0);
    let c = cfg("greedy", 64);
    let srtf = policy_by_name("srtf", &pool).unwrap();
    let report = cluster::run_cluster(&pool, &queue, srtf.as_ref(), &c, 42).unwrap();
    assert!(
        report.timeline.iter().any(|e| e.kind == EventKind::Preempt),
        "the tight mix must actually exercise preemption under srtf"
    );
    check_ledger(&report, &pool, "tight/srtf");
    // Preempted jobs still finish.
    assert_eq!(report.completed(), queue.len());

    // The non-preemptive policies must balance too.
    for name in ["fifo", "drf-cost"] {
        let p = policy_by_name(name, &pool).unwrap();
        let r = cluster::run_cluster(&pool, &queue, p.as_ref(), &c, 42).unwrap();
        check_ledger(&r, &pool, &format!("tight/{name}"));
    }
    // And on the heterogeneous pool with the generic mix.
    let pool = simulated_types(2, true);
    let queue = uniform_mix(6, 11, 20_000.0);
    for name in cluster::policy_names() {
        let p = policy_by_name(name, &pool).unwrap();
        let r = cluster::run_cluster(&pool, &queue, p.as_ref(), &c, 11).unwrap();
        check_ledger(&r, &pool, &format!("uniform/{name}"));
    }
}

#[test]
fn fifo_equals_srtf_on_a_single_job() {
    // With one tenant there is nothing to order or preempt: the two
    // policies must produce bit-identical runs, not merely similar ones.
    let pool = paper_testbed();
    let queue = uniform_mix(1, 9, 20_000.0);
    let c = cfg("greedy", 64);
    let fifo = policy_by_name("fifo", &pool).unwrap();
    let srtf = policy_by_name("srtf", &pool).unwrap();
    let a = cluster::run_cluster(&pool, &queue, fifo.as_ref(), &c, 9).unwrap();
    let b = cluster::run_cluster(&pool, &queue, srtf.as_ref(), &c, 9).unwrap();
    assert_reports_bit_identical(&a, &b, "single-job fifo vs srtf");
    assert_eq!(a.policy, "fifo");
    assert_eq!(b.policy, "srtf");
}

#[test]
fn tight_mix_separates_the_policies() {
    // The fig15 acceptance shape, exercised at test speed: srtf and
    // drf-cost each strictly beat fifo on mean JCT for the bundled
    // contention mix (head-of-line blocking is FIFO's whole cost).
    let pool = tight_pool();
    let queue = tight_mix(6, 42, 20_000.0);
    let c = cfg("greedy", 64);
    let reports = cluster::run_all_policies(&pool, &queue, &c, 42).unwrap();
    let by_name = |n: &str| reports.iter().find(|r| r.policy == n).unwrap();
    let fifo = by_name("fifo");
    for challenger in ["srtf", "drf-cost"] {
        let r = by_name(challenger);
        assert!(
            r.mean_jct_secs() < fifo.mean_jct_secs(),
            "{challenger} mean JCT {:.0} s !< fifo {:.0} s",
            r.mean_jct_secs(),
            fifo.mean_jct_secs()
        );
    }
}
