//! Integration tests for the observability layer (DESIGN.md
//! §Observability): the tracer is provably inert — schedule, cluster and
//! serve outcomes are bit-identical with tracing on or off — and the
//! virtual-clock portion of a trace is itself bit-deterministic per
//! (config, seed). Every produced trace must pass `lint_trace` in both
//! export formats.

use heterps::cluster::{self, policy_by_name, steady_mix, tight_mix, tight_pool, ClusterConfig};
use heterps::cost::{CostConfig, CostModel};
use heterps::model::zoo;
use heterps::obs::{lint_trace, Tracer};
use heterps::resources::paper_testbed;
use heterps::sched::{self, Budget, EvalEngine, SchedulerSpec};
use heterps::serve::{self, admission_digest, ClockMode, ServeConfig};

fn cluster_cfg(method: &str) -> ClusterConfig {
    ClusterConfig {
        spec: SchedulerSpec::parse(method).unwrap(),
        admit_budget_evals: 48,
        ..Default::default()
    }
}

fn serve_cfg(method: &str) -> ServeConfig {
    ServeConfig {
        cluster: cluster_cfg(method),
        policy: "drf-cost".to_string(),
        probe: None,
        clock: ClockMode::Virtual,
        progress_every: 0,
        stats_every: 0,
    }
}

/// Drop wall-stamped records: their presence and order are deterministic
/// but their timestamps are not, so the determinism diff runs on the
/// virtual-clock remainder (the `grep -v '"wall": true'` convention
/// verify.sh uses).
fn virtual_lines(trace: &str) -> String {
    trace.lines().filter(|l| !l.contains("\"wall\": true")).collect::<Vec<_>>().join("\n")
}

#[test]
fn tracing_is_inert_for_schedule_sessions() {
    // One deterministic and one stochastic method: the tracer must not
    // touch the seed stream, the cache accounting or the incumbent.
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for method in ["greedy", "rl-tabular:rounds=10"] {
        let spec = SchedulerSpec::parse(method).unwrap();
        let scheduler = spec.build(42);
        let mut session = scheduler.session_engine(EvalEngine::new(&cm), Budget::evals(200));
        let base = sched::drive(session.as_mut(), None).unwrap();

        let tracer = Tracer::new();
        let scheduler = spec.build(42);
        let engine = EvalEngine::new(&cm).with_tracer(tracer.clone());
        let mut session = scheduler.session_engine(engine, Budget::evals(200));
        let traced = sched::drive_traced(session.as_mut(), None, &tracer).unwrap();

        assert_eq!(base.plan, traced.plan, "{method}: tracing changed the plan");
        assert_eq!(
            base.eval.cost_usd.to_bits(),
            traced.eval.cost_usd.to_bits(),
            "{method}: tracing changed the cost"
        );
        assert_eq!(
            (base.evaluations, base.cache_hits),
            (traced.evaluations, traced.cache_hits),
            "{method}: tracing changed the evaluation accounting"
        );

        // The trace itself is well-formed: balanced spans, both formats.
        assert_eq!(tracer.open_spans(), 0, "{method}: spans left open");
        let lint = lint_trace(&tracer.render_jsonl()).unwrap();
        assert!(lint.spans >= 2, "{method}: expected session + step spans, got {}", lint.spans);
        assert!(lint.events >= 1, "{method}: expected eval events");
        let chrome = lint_trace(&tracer.to_chrome_json().render()).unwrap();
        assert_eq!((chrome.spans, chrome.events), (lint.spans, lint.events), "{method}: chrome");
    }
}

#[test]
fn tracing_is_inert_for_cluster_runs_and_traces_are_deterministic() {
    // drf-cost is the plain path; srtf on the tight mix exercises the
    // preemption-campaign spans.
    let pool = tight_pool();
    let queue = tight_mix(6, 42, 20_000.0);
    let cfg = cluster_cfg("greedy");
    for policy_name in ["drf-cost", "srtf"] {
        let p = policy_by_name(policy_name, &pool).unwrap();
        let base = cluster::run_cluster(&pool, &queue, p.as_ref(), &cfg, 42).unwrap();

        let t1 = Tracer::new();
        let p = policy_by_name(policy_name, &pool).unwrap();
        let a = cluster::run_cluster_traced(&pool, &queue, p.as_ref(), &cfg, 42, &t1).unwrap();
        let t2 = Tracer::new();
        let p = policy_by_name(policy_name, &pool).unwrap();
        let b = cluster::run_cluster_traced(&pool, &queue, p.as_ref(), &cfg, 42, &t2).unwrap();

        // Inert: the traced report is the untraced report, bit for bit.
        assert_eq!(
            admission_digest(&base),
            admission_digest(&a),
            "{policy_name}: tracing perturbed the admission timeline"
        );
        assert_eq!(admission_digest(&a), admission_digest(&b), "{policy_name}: rerun digest");
        assert_eq!(
            base.makespan_secs.to_bits(),
            a.makespan_secs.to_bits(),
            "{policy_name}: makespan"
        );
        assert_eq!(
            base.cumulative_cost_usd.to_bits(),
            a.cumulative_cost_usd.to_bits(),
            "{policy_name}: cost"
        );
        assert_eq!(base.total_evaluations, a.total_evaluations, "{policy_name}: evaluations");

        // Deterministic: the virtual-clock records of two runs are
        // bit-identical (wall-stamped records keep deterministic
        // presence/order/seq but carry real timestamps).
        let ta = t1.render_jsonl();
        let tb = t2.render_jsonl();
        assert_eq!(virtual_lines(&ta), virtual_lines(&tb), "{policy_name}: trace determinism");
        assert_ne!(virtual_lines(&ta), "", "{policy_name}: no virtual-clock records at all");

        let lint = lint_trace(&ta).unwrap();
        assert!(lint.spans >= 1, "{policy_name}: no spans");
        assert!(lint.events >= queue.len(), "{policy_name}: fewer events than arrivals");
        assert!(lint.wall_records >= 1, "{policy_name}: decision latency not wall-stamped");
        if policy_name == "srtf" {
            assert!(
                ta.contains("preempt_campaign"),
                "srtf on the tight mix must trace a preemption campaign"
            );
        }
    }
}

#[test]
fn tracing_is_inert_for_serve_and_metrics_snapshot_is_populated() {
    let pool = tight_pool();
    let queue = steady_mix(60, 11, 20_000.0);
    let cfg = serve_cfg("greedy");
    let base = serve::run_serve(&pool, &queue, &cfg, 11).unwrap();

    let t1 = Tracer::new();
    let a = serve::run_serve_traced(&pool, &queue, &cfg, 11, &t1).unwrap();
    let t2 = Tracer::new();
    let b = serve::run_serve_traced(&pool, &queue, &cfg, 11, &t2).unwrap();

    assert_eq!(
        base.admission_digest, a.admission_digest,
        "tracing perturbed serve admission decisions"
    );
    assert_eq!(a.admission_digest, b.admission_digest, "rerun digest");
    assert_eq!(virtual_lines(&t1.render_jsonl()), virtual_lines(&t2.render_jsonl()));

    let lint = lint_trace(&t1.render_jsonl()).unwrap();
    assert!(lint.spans >= 1 && lint.events >= queue.len(), "serve trace too sparse: {lint:?}");
    assert!(t1.render_jsonl().contains("\"tick\""), "no per-arrival tick events");

    // The --metrics-out snapshot: named, non-empty, and in agreement
    // with the report it was taken from.
    assert!(!a.metrics.is_empty(), "metrics snapshot is empty");
    for name in ["cluster.decisions", "cluster.cost_usd", "eval.charged"] {
        assert!(a.metrics.get(name).is_some(), "metrics snapshot lacks `{name}`");
    }
    let line = a.metrics.stats_line();
    assert!(line.contains("cluster.decisions="), "stats line lacks decisions: {line}");
    let rendered = a.metrics.to_json().render();
    assert!(rendered.contains("cluster.decision_lat_us"), "histogram missing from dump");
}
