//! Integration tests for the observability layer (DESIGN.md
//! §Observability): the tracer is provably inert — schedule, cluster and
//! serve outcomes are bit-identical with tracing on or off — and the
//! virtual-clock portion of a trace is itself bit-deterministic per
//! (config, seed). Every produced trace must pass `lint_trace` in both
//! export formats.

use heterps::cluster::{self, policy_by_name, steady_mix, tight_mix, tight_pool, ClusterConfig};
use heterps::cost::{CostConfig, CostModel};
use heterps::metrics::Histogram;
use heterps::model::zoo;
use heterps::obs::{lint_trace, profile_trace, MetricValue, MetricsRegistry, Tracer, WatchConfig};
use heterps::resources::paper_testbed;
use heterps::sched::{self, Budget, EvalEngine, SchedulerSpec};
use heterps::serve::{self, admission_digest, ClockMode, ServeConfig};

fn cluster_cfg(method: &str) -> ClusterConfig {
    ClusterConfig {
        spec: SchedulerSpec::parse(method).unwrap(),
        admit_budget_evals: 48,
        ..Default::default()
    }
}

fn serve_cfg(method: &str) -> ServeConfig {
    ServeConfig {
        cluster: cluster_cfg(method),
        policy: "drf-cost".to_string(),
        probe: None,
        clock: ClockMode::Virtual,
        progress_every: 0,
        stats_every: 0,
        watch: None,
    }
}

/// Drop wall-stamped records: their presence and order are deterministic
/// but their timestamps are not, so the determinism diff runs on the
/// virtual-clock remainder (the `grep -v '"wall": true'` convention
/// verify.sh uses).
fn virtual_lines(trace: &str) -> String {
    trace.lines().filter(|l| !l.contains("\"wall\": true")).collect::<Vec<_>>().join("\n")
}

#[test]
fn tracing_is_inert_for_schedule_sessions() {
    // One deterministic and one stochastic method: the tracer must not
    // touch the seed stream, the cache accounting or the incumbent.
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for method in ["greedy", "rl-tabular:rounds=10"] {
        let spec = SchedulerSpec::parse(method).unwrap();
        let scheduler = spec.build(42);
        let mut session = scheduler.session_engine(EvalEngine::new(&cm), Budget::evals(200));
        let base = sched::drive(session.as_mut(), None).unwrap();

        let tracer = Tracer::new();
        let scheduler = spec.build(42);
        let engine = EvalEngine::new(&cm).with_tracer(tracer.clone());
        let mut session = scheduler.session_engine(engine, Budget::evals(200));
        let traced = sched::drive_traced(session.as_mut(), None, &tracer).unwrap();

        assert_eq!(base.plan, traced.plan, "{method}: tracing changed the plan");
        assert_eq!(
            base.eval.cost_usd.to_bits(),
            traced.eval.cost_usd.to_bits(),
            "{method}: tracing changed the cost"
        );
        assert_eq!(
            (base.evaluations, base.cache_hits),
            (traced.evaluations, traced.cache_hits),
            "{method}: tracing changed the evaluation accounting"
        );

        // The trace itself is well-formed: balanced spans, both formats.
        assert_eq!(tracer.open_spans(), 0, "{method}: spans left open");
        let lint = lint_trace(&tracer.render_jsonl()).unwrap();
        assert!(lint.spans >= 2, "{method}: expected session + step spans, got {}", lint.spans);
        assert!(lint.events >= 1, "{method}: expected eval events");
        let chrome = lint_trace(&tracer.to_chrome_json().render()).unwrap();
        assert_eq!((chrome.spans, chrome.events), (lint.spans, lint.events), "{method}: chrome");
    }
}

#[test]
fn tracing_is_inert_for_cluster_runs_and_traces_are_deterministic() {
    // drf-cost is the plain path; srtf on the tight mix exercises the
    // preemption-campaign spans.
    let pool = tight_pool();
    let queue = tight_mix(6, 42, 20_000.0);
    let cfg = cluster_cfg("greedy");
    for policy_name in ["drf-cost", "srtf"] {
        let p = policy_by_name(policy_name, &pool).unwrap();
        let base = cluster::run_cluster(&pool, &queue, p.as_ref(), &cfg, 42).unwrap();

        let t1 = Tracer::new();
        let p = policy_by_name(policy_name, &pool).unwrap();
        let a = cluster::run_cluster_traced(&pool, &queue, p.as_ref(), &cfg, 42, &t1).unwrap();
        let t2 = Tracer::new();
        let p = policy_by_name(policy_name, &pool).unwrap();
        let b = cluster::run_cluster_traced(&pool, &queue, p.as_ref(), &cfg, 42, &t2).unwrap();

        // Inert: the traced report is the untraced report, bit for bit.
        assert_eq!(
            admission_digest(&base),
            admission_digest(&a),
            "{policy_name}: tracing perturbed the admission timeline"
        );
        assert_eq!(admission_digest(&a), admission_digest(&b), "{policy_name}: rerun digest");
        assert_eq!(
            base.makespan_secs.to_bits(),
            a.makespan_secs.to_bits(),
            "{policy_name}: makespan"
        );
        assert_eq!(
            base.cumulative_cost_usd.to_bits(),
            a.cumulative_cost_usd.to_bits(),
            "{policy_name}: cost"
        );
        assert_eq!(base.total_evaluations, a.total_evaluations, "{policy_name}: evaluations");

        // Deterministic: the virtual-clock records of two runs are
        // bit-identical (wall-stamped records keep deterministic
        // presence/order/seq but carry real timestamps).
        let ta = t1.render_jsonl();
        let tb = t2.render_jsonl();
        assert_eq!(virtual_lines(&ta), virtual_lines(&tb), "{policy_name}: trace determinism");
        assert_ne!(virtual_lines(&ta), "", "{policy_name}: no virtual-clock records at all");

        let lint = lint_trace(&ta).unwrap();
        assert!(lint.spans >= 1, "{policy_name}: no spans");
        assert!(lint.events >= queue.len(), "{policy_name}: fewer events than arrivals");
        assert!(lint.wall_records >= 1, "{policy_name}: decision latency not wall-stamped");
        if policy_name == "srtf" {
            assert!(
                ta.contains("preempt_campaign"),
                "srtf on the tight mix must trace a preemption campaign"
            );
        }
    }
}

#[test]
fn tracing_is_inert_for_serve_and_metrics_snapshot_is_populated() {
    let pool = tight_pool();
    let queue = steady_mix(60, 11, 20_000.0);
    let cfg = serve_cfg("greedy");
    let base = serve::run_serve(&pool, &queue, &cfg, 11).unwrap();

    let t1 = Tracer::new();
    let a = serve::run_serve_traced(&pool, &queue, &cfg, 11, &t1).unwrap();
    let t2 = Tracer::new();
    let b = serve::run_serve_traced(&pool, &queue, &cfg, 11, &t2).unwrap();

    assert_eq!(
        base.admission_digest, a.admission_digest,
        "tracing perturbed serve admission decisions"
    );
    assert_eq!(a.admission_digest, b.admission_digest, "rerun digest");
    assert_eq!(virtual_lines(&t1.render_jsonl()), virtual_lines(&t2.render_jsonl()));

    let lint = lint_trace(&t1.render_jsonl()).unwrap();
    assert!(lint.spans >= 1 && lint.events >= queue.len(), "serve trace too sparse: {lint:?}");
    assert!(t1.render_jsonl().contains("\"tick\""), "no per-arrival tick events");

    // The --metrics-out snapshot: named, non-empty, and in agreement
    // with the report it was taken from.
    assert!(!a.metrics.is_empty(), "metrics snapshot is empty");
    for name in ["cluster.decisions", "cluster.cost_usd", "eval.charged"] {
        assert!(a.metrics.get(name).is_some(), "metrics snapshot lacks `{name}`");
    }
    let line = a.metrics.stats_line();
    assert!(line.contains("cluster.decisions="), "stats line lacks decisions: {line}");
    let rendered = a.metrics.to_json().render();
    assert!(rendered.contains("cluster.decision_lat_us"), "histogram missing from dump");
}

/// The PR 9 acceptance contract for `trace-profile`: on a real cluster
/// trace (preemptions included), every completed job's JCT decomposes
/// into queueing / admission-search / running / below-floor segments
/// that sum back to the JCT, and the queueing + search + below-floor
/// side of the split reproduces the simulator's own SLA-violation
/// accounting.
#[test]
fn trace_profile_decomposes_every_jct_on_a_real_cluster_trace() {
    let pool = tight_pool();
    let queue = tight_mix(6, 42, 20_000.0);
    let cfg = cluster_cfg("greedy");
    let tracer = Tracer::new();
    let policy = policy_by_name("srtf", &pool).unwrap();
    let report =
        cluster::run_cluster_traced(&pool, &queue, policy.as_ref(), &cfg, 42, &tracer).unwrap();
    let profile = profile_trace(&tracer.render_jsonl()).unwrap();

    assert_eq!(profile.jobs.len(), queue.len(), "one attribution per arrival");
    let mut completed = 0usize;
    let mut viol = 0.0f64;
    for j in &profile.jobs {
        let Some(jct) = j.jct_secs() else { continue };
        completed += 1;
        let sum = j.segments_sum_secs();
        assert!(
            (sum - jct).abs() <= 1e-6 * jct.max(1.0),
            "job {}: segments {sum} != jct {jct} \
             (queue {} + search {} + run {} + below {})",
            j.job,
            j.queueing_secs,
            j.search_secs,
            j.running_secs,
            j.below_floor_secs
        );
        assert!(
            j.queueing_secs >= 0.0
                && j.search_secs >= 0.0
                && j.running_secs >= 0.0
                && j.below_floor_secs >= 0.0,
            "job {}: negative segment",
            j.job
        );
        viol += j.queueing_secs + j.search_secs + j.below_floor_secs;
    }
    assert_eq!(completed, report.completed(), "completed-job count mismatch");
    let report_viol = report.total_sla_violation_secs();
    assert!(
        (viol - report_viol).abs() <= 1e-6 * report_viol.max(1.0),
        "attributed violation {viol} != simulator violation {report_viol}"
    );
    let preempts: u64 = profile.jobs.iter().map(|j| j.preemptions).sum();
    let report_preempts: u64 = report.jobs.iter().map(|j| j.preemptions as u64).sum();
    assert_eq!(preempts, report_preempts, "preemption counts diverge");
    assert!(preempts >= 1, "srtf on the tight mix must preempt for the test to bite");

    // The critical path is chronological and ends at the final completion.
    assert!(!profile.critical_path.is_empty(), "no critical path on a completed run");
    for pair in profile.critical_path.windows(2) {
        assert!(pair[0].to_secs <= pair[1].from_secs + 1e-9, "critical path not chronological");
    }
    let last = profile.critical_path.last().unwrap();
    assert!(
        (last.to_secs - report.makespan_secs).abs() <= 1e-6,
        "critical path ends at {}, makespan {}",
        last.to_secs,
        report.makespan_secs
    );

    // Deterministic per trace: profiling the identical text twice renders
    // identically, and the chrome export profiles to the same attribution.
    let again = profile_trace(&tracer.render_jsonl()).unwrap();
    assert_eq!(profile.render(), again.render());
    assert_eq!(profile.to_json().render(), again.to_json().render());
}

/// The PR 9 watchdog contract: enabling `--watch` changes neither the
/// admission digest nor the cost bits, and two watchdog runs raise
/// bit-identical virtual-clock alert streams.
#[test]
fn watchdog_is_inert_and_virtual_alerts_are_bit_deterministic() {
    let pool = tight_pool();
    let queue = steady_mix(80, 11, 20_000.0);
    let off = serve_cfg("greedy");
    let base = serve::run_serve(&pool, &queue, &off, 11).unwrap();
    assert!(base.alerts.is_none(), "watchdog off must report no alert stream");
    assert!(
        base.report.total_sla_violation_secs() > 0.0,
        "precondition: the tight pool must accrue SLA violations for the streak detector"
    );

    let mut on = serve_cfg("greedy");
    on.stats_every = 5;
    on.watch = Some(WatchConfig { raise: 1, clear: 1, util_floor: 0.0, ..Default::default() });
    let t1 = Tracer::new();
    let a = serve::run_serve_traced(&pool, &queue, &on, 11, &t1).unwrap();
    let t2 = Tracer::new();
    let b = serve::run_serve_traced(&pool, &queue, &on, 11, &t2).unwrap();

    // Inert: watchdog-on == watchdog-off, bit for bit.
    assert_eq!(base.admission_digest, a.admission_digest, "watchdog perturbed admissions");
    assert_eq!(
        base.report.cumulative_cost_usd.to_bits(),
        a.report.cumulative_cost_usd.to_bits(),
        "watchdog perturbed the cost bits"
    );
    assert_eq!(
        base.report.makespan_secs.to_bits(),
        a.report.makespan_secs.to_bits(),
        "watchdog perturbed the makespan"
    );
    assert_eq!(a.admission_digest, b.admission_digest, "rerun digest");

    // Bit-identical virtual alert streams across reruns (wall-clock
    // detectors are exempt: their inputs are real time).
    let virt_alerts = |o: &serve::ServeOutcome| -> Vec<(String, u64, u64, usize)> {
        o.alerts
            .as_ref()
            .expect("watchdog on")
            .iter()
            .filter(|al| !al.wall)
            .map(|al| {
                (al.detector.to_string(), al.at_secs.to_bits(), al.value.to_bits(), al.streak)
            })
            .collect()
    };
    let va = virt_alerts(&a);
    assert_eq!(va, virt_alerts(&b), "virtual alert streams diverged across reruns");
    assert!(
        !va.is_empty(),
        "a tight pool accruing {} s of SLA violation must raise the streak detector",
        a.report.total_sla_violation_secs()
    );

    // The typed `alert` trace events are part of the deterministic
    // virtual-clock trace, one per virtual alert.
    let j1 = t1.render_jsonl();
    assert_eq!(virtual_lines(&j1), virtual_lines(&t2.render_jsonl()));
    let traced_virtual_alerts = virtual_lines(&j1)
        .lines()
        .filter(|l| l.contains("\"alert\""))
        .count();
    assert_eq!(traced_virtual_alerts, va.len(), "trace and outcome disagree on alerts");
    lint_trace(&j1).unwrap();
}

/// Satellite: registry snapshots keep insertion order across reruns, the
/// two watchdog input gauges are present, and the Histogram mean/count
/// accessors round-trip through `observe_histogram` (the watchdog's p99
/// baseline path).
#[test]
fn metrics_registry_snapshots_are_insertion_order_stable() {
    let snapshot_names = || -> Vec<String> {
        let pool = tight_pool();
        let queue = steady_mix(30, 7, 20_000.0);
        let out = serve::run_serve(&pool, &queue, &serve_cfg("greedy"), 7).unwrap();
        out.metrics
            .to_json()
            .as_obj()
            .expect("registry dump is an object")
            .iter()
            .map(|(k, _)| k.clone())
            .collect()
    };
    let names = snapshot_names();
    assert_eq!(names, snapshot_names(), "registry name order varied across reruns");
    for required in ["cluster.clock_secs", "cluster.sla_viol_secs", "cluster.util_mean"] {
        assert!(names.iter().any(|n| n == required), "snapshot lacks `{required}`: {names:?}");
    }
    assert_eq!(names[0], "cluster.clock_secs", "clock must lead the stats line");

    let h = Histogram::new(8);
    for v in [1, 2, 3] {
        h.record(v);
    }
    assert_eq!(h.count(), 3);
    assert!((h.mean() - 2.0).abs() < 1e-12);
    let mut reg = MetricsRegistry::new();
    reg.observe_histogram("lat", &h, 2.0);
    match reg.get("lat") {
        Some(MetricValue::Histogram { count, mean, .. }) => {
            assert_eq!(*count, 3);
            assert!((mean - 4.0).abs() < 1e-12, "scale must apply to the mean, got {mean}");
        }
        other => panic!("expected a histogram snapshot, got {other:?}"),
    }
}
