//! Integration tests for the serve daemon: bit-deterministic admission
//! across reruns and with the probe on or off, ledger conservation under
//! a 10k-job stream, probe convergence inside the full loop, and
//! actionable rejection of malformed streams.

use heterps::cluster::{steady_mix, tight_pool, ClusterConfig, ClusterReport, EventKind};
use heterps::resources::ResourcePool;
use heterps::sched::SchedulerSpec;
use heterps::serve::{self, parse_stream, render_stream, ClockMode, ProbeConfig, ServeConfig};

fn serve_cfg(method: &str, budget: usize) -> ServeConfig {
    ServeConfig {
        cluster: ClusterConfig {
            spec: SchedulerSpec::parse(method).unwrap(),
            admit_budget_evals: budget,
            ..Default::default()
        },
        policy: "drf-cost".to_string(),
        probe: None,
        clock: ClockMode::Virtual,
        progress_every: 0,
        stats_every: 0,
        watch: None,
    }
}

/// Replay a report's unit ledger (the serve twin of the cluster test):
/// every `Admit` acquires its whole sub-pool, every `Preempt`/`Complete`
/// releases exactly what the job held, and the running total never
/// exceeds the parent pool's per-type limits.
fn check_ledger(report: &ClusterReport, pool: &ResourcePool, ctx: &str) {
    let nt = pool.num_types();
    let mut held: Vec<Option<Vec<usize>>> = vec![None; report.jobs.len()];
    let mut total = vec![0usize; nt];
    for ev in &report.timeline {
        match ev.kind {
            EventKind::Arrive | EventKind::Reject => {
                assert!(ev.units.is_empty(), "{ctx}: {:?} carries units", ev.kind);
            }
            EventKind::Admit => {
                assert!(
                    held[ev.job_id].is_none(),
                    "{ctx}: job {} admitted while already holding a sub-pool",
                    ev.job_id
                );
                assert_eq!(ev.units.len(), nt, "{ctx}: unit arity");
                for (t, &u) in ev.units.iter().enumerate() {
                    total[t] += u;
                    assert!(
                        total[t] <= pool.get(t).max_units,
                        "{ctx}: type {t} over limit after admitting job {}",
                        ev.job_id
                    );
                }
                held[ev.job_id] = Some(ev.units.clone());
            }
            EventKind::Preempt | EventKind::Complete => {
                let h = held[ev.job_id].take().unwrap_or_else(|| {
                    panic!("{ctx}: job {} released units it never held", ev.job_id)
                });
                assert_eq!(
                    h, ev.units,
                    "{ctx}: job {} released a sub-pool it did not acquire",
                    ev.job_id
                );
                for (t, &u) in ev.units.iter().enumerate() {
                    total[t] -= u;
                }
            }
        }
    }
    for (jid, h) in held.iter().enumerate() {
        assert!(h.is_none(), "{ctx}: job {jid} still holds a sub-pool at the end");
    }
    assert!(total.iter().all(|&u| u == 0), "{ctx}: units leaked");
}

#[test]
fn serve_runs_are_bit_deterministic_probe_on_or_off() {
    // The daemon contract: identical (pool, stream, config, seed) means
    // an identical admission digest — rerun to rerun, and with the probe
    // enabled (which may only move wall-clock throughput, never the
    // decisions). One deterministic and one stochastic per-job method,
    // and the stream goes through the JSONL codec first so the
    // serialized path the CLI takes is covered too.
    let pool = tight_pool();
    let queue = parse_stream(&render_stream(&steady_mix(60, 11, 20_000.0))).unwrap();
    for method in ["greedy", "rl-tabular:rounds=10"] {
        let cfg = serve_cfg(method, 64);
        let a = serve::run_serve(&pool, &queue, &cfg, 11).unwrap();
        let b = serve::run_serve(&pool, &queue, &cfg, 11).unwrap();
        assert_eq!(a.admission_digest, b.admission_digest, "{method}: rerun digest");
        assert_eq!(a.report.decisions, b.report.decisions, "{method}: decisions");

        let mut probed = serve_cfg(method, 64);
        probed.probe = Some(ProbeConfig { window: 8, ..Default::default() });
        let c = serve::run_serve(&pool, &queue, &probed, 11).unwrap();
        assert_eq!(
            a.admission_digest, c.admission_digest,
            "{method}: the probe perturbed admission decisions"
        );
    }
}

#[test]
fn a_ten_thousand_job_stream_conserves_the_ledger() {
    // Production scale: 10k arrivals through the streaming loop. Every
    // job must resolve (completed or rejected), the unit ledger must
    // balance through every handoff, and a rerun must land on the same
    // digest.
    let pool = tight_pool();
    let queue = steady_mix(10_000, 42, 20_000.0);
    let cfg = serve_cfg("greedy", 16);
    let a = serve::run_serve(&pool, &queue, &cfg, 42).unwrap();
    let b = serve::run_serve(&pool, &queue, &cfg, 42).unwrap();
    assert_eq!(a.admission_digest, b.admission_digest, "10k digest");
    assert_eq!(a.arrivals, 10_000);
    assert_eq!(a.report.completed() + a.report.rejected, 10_000, "jobs left unresolved");
    assert!(a.report.decisions >= 10_000, "fewer decisions than arrivals");
    check_ledger(&a.report, &pool, "serve/10k");
}

#[test]
fn the_probe_tunes_threads_inside_the_daemon() {
    // With a short window the probe must actually fire: at least one
    // applied adjustment, never outside [min, max], and — the core
    // guarantee — a digest identical to the probe-less run.
    let pool = tight_pool();
    let queue = steady_mix(300, 7, 20_000.0);
    let plain = serve_cfg("greedy", 32);
    let base = serve::run_serve(&pool, &queue, &plain, 7).unwrap();
    let mut cfg = serve_cfg("greedy", 32);
    cfg.probe = Some(ProbeConfig {
        min_threads: 1,
        max_threads: 4,
        window: 4,
        ..Default::default()
    });
    let out = serve::run_serve(&pool, &queue, &cfg, 7).unwrap();
    let p = out.probe.expect("probe summary present");
    assert!(p.observations >= 4, "probe barely fired: {} windows", p.observations);
    assert!(p.adjustments >= 1, "probe never moved the concurrency");
    assert!(p.max_applied > p.initial_threads, "probe never left the initial setting");
    assert!(
        p.min_applied >= 1 && p.max_applied <= 4,
        "probe left [1, 4]: applied [{}, {}]",
        p.min_applied,
        p.max_applied
    );
    assert_eq!(out.final_eval_threads, p.final_threads);
    assert_eq!(
        base.admission_digest, out.admission_digest,
        "self-tuning perturbed admission decisions"
    );
}

#[test]
fn malformed_streams_are_rejected_with_line_context() {
    let ok = r#"{"at": 0.0, "model": "nce", "floor": 9000.0, "samples": 4.0e6}"#;
    for (bad, needle) in [
        ("not json", "line 2"),
        (r#"{"at": -1.0, "model": "nce", "floor": 1.0, "samples": 1.0}"#, "line 2"),
        (r#"{"at": 0.5, "model": "warpnet", "floor": 1.0, "samples": 1.0}"#, "warpnet"),
    ] {
        let text = format!("{ok}\n{bad}\n");
        let err = parse_stream(&text).expect_err("malformed line accepted");
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "error lacks `{needle}`: {msg}");
    }
}
