//! Chaos propcheck suite for the membership engine: randomized seeded
//! [`FaultPlan`]s across worker counts and staleness bounds must replay
//! bit-identically, survivors must converge, killing every worker but
//! one must not deadlock the barrier, an empty plan must match the
//! fixed-membership engine, and trace-derived `pool_frac` plans must
//! drive real evictions and recoveries.

use heterps::comm::{
    run_membership, run_sync_reference, CommConfig, FaultEvent, FaultPlan, MembershipReport,
};
use heterps::data::compress::Codec;
use heterps::obs::Tracer;
use heterps::resources::paper_testbed;
use heterps::train::ParamServer;

fn cfg(workers: usize, staleness: u64, codec: Codec) -> CommConfig {
    CommConfig {
        workers,
        steps: 6,
        rows: 8,
        slots: 4,
        dim: 8,
        vocab: 300,
        staleness,
        codec,
        compute_ms: 0.0,
        seed: 42,
        ..Default::default()
    }
}

fn store(c: &CommConfig) -> ParamServer {
    ParamServer::new(c.dim, 8, 0.3, c.seed)
}

fn run(c: &CommConfig, plan: &FaultPlan) -> MembershipReport {
    let pool = paper_testbed();
    let s = store(c);
    run_membership(c, &pool, &s, plan, &Tracer::disabled()).expect("membership run")
}

fn assert_bit_identical(a: &MembershipReport, b: &MembershipReport, ctx: &str) {
    assert_eq!(a.digest, b.digest, "{ctx}: digest");
    assert_eq!(a.virtual_secs.to_bits(), b.virtual_secs.to_bits(), "{ctx}: virtual clock");
    assert_eq!(a.server, b.server, "{ctx}: server stats");
    assert_eq!(a.epoch, b.epoch, "{ctx}: epoch");
    assert_eq!(a.samples, b.samples, "{ctx}: samples");
    assert_eq!(
        a.snapshot.recovery_secs.to_bits(),
        b.snapshot.recovery_secs.to_bits(),
        "{ctx}: recovery time"
    );
    assert_eq!(
        (a.snapshot.joins, a.snapshot.leaves, a.snapshot.failures),
        (b.snapshot.joins, b.snapshot.leaves, b.snapshot.failures),
        "{ctx}: membership counters"
    );
}

#[test]
fn random_seeded_plans_replay_bit_identically_and_survivors_converge() {
    for workers in [3usize, 4] {
        for staleness in [0u64, 2] {
            for plan_seed in 0u64..6 {
                let c = cfg(workers, staleness, Codec::SparseF16);
                let plan = FaultPlan::seeded(plan_seed, c.workers, c.steps);
                let ctx = format!("w{workers}/s{staleness}/seed{plan_seed}");
                let a = run(&c, &plan);
                let b = run(&c, &plan);
                assert_bit_identical(&a, &b, &ctx);
                // Worker 0 is always spared by seeded plans: at least its
                // full stream of pushes survives whatever the plan does
                // to the rest, and the table genuinely trained.
                assert!(
                    a.server.applied_pushes >= c.steps as u64,
                    "{ctx}: survivors applied {} < {} pushes",
                    a.server.applied_pushes,
                    c.steps
                );
                assert!(a.digest != 0, "{ctx}: degenerate digest");
                assert!(a.virtual_secs > 0.0, "{ctx}: no virtual time elapsed");
                // Metric coherence: every eviction is a failure tick and
                // every rejoin handoff accrues recovery time.
                assert_eq!(a.snapshot.failures, a.server.evictions, "{ctx}: failures");
                assert_eq!(a.snapshot.joins, a.server.joins, "{ctx}: joins");
                if a.server.joins > 0 {
                    assert!(a.snapshot.recovery_secs > 0.0, "{ctx}: free recovery");
                }
            }
        }
    }
}

#[test]
fn killing_all_but_one_worker_neither_deadlocks_nor_drops_durable_pushes() {
    for staleness in [0u64, 2] {
        let c = cfg(4, staleness, Codec::SparseF16);
        let plan = FaultPlan {
            events: vec![
                FaultEvent::Kill { worker: 1, at_step: 1 },
                FaultEvent::Kill { worker: 2, at_step: 2 },
                FaultEvent::Kill { worker: 3, at_step: 3 },
            ],
            ..Default::default()
        };
        let r = run(&c, &plan);
        assert_eq!(r.server.evictions, 3, "staleness {staleness}: evictions");
        assert_eq!(r.server.joins, 0, "staleness {staleness}: no restarts scripted");
        // Only the lone survivor says a graceful goodbye.
        assert_eq!(r.snapshot.leaves, 1, "staleness {staleness}: leaves");
        // Worker 0 runs every step; workers 1..3 land exactly the pushes
        // for the steps they completed before their scripted kill.
        assert_eq!(
            r.server.applied_pushes,
            (c.steps + 1 + 2 + 3) as u64,
            "staleness {staleness}: durable pushes"
        );
        // Epoch = 1 bye + 3 evictions on top of the starting membership.
        assert_eq!(r.epoch, 4, "staleness {staleness}: epoch");
    }
}

#[test]
fn empty_plan_is_bit_identical_to_the_fixed_membership_engine() {
    for staleness in [0u64, 2] {
        for codec in [Codec::F32, Codec::SparseF16] {
            let c = cfg(3, staleness, codec);
            let ctx = format!("s{staleness}/{codec:?}");
            let a = run(&c, &FaultPlan::empty());
            let b = run(&c, &FaultPlan::empty());
            assert_bit_identical(&a, &b, &ctx);
            assert_eq!(a.server.evictions, 0, "{ctx}: phantom eviction");
            assert_eq!(a.snapshot.recovery_secs, 0.0, "{ctx}: phantom recovery");
            assert_eq!(
                a.server.applied_pushes,
                (c.workers * c.steps) as u64,
                "{ctx}: every push lands"
            );
            if staleness == 0 {
                // No faults + barrier = the synchronous reference, and the
                // threaded engine's own staleness-0 contract ties it to
                // `run_async` as well.
                let sync = run_sync_reference(&c, &store(&c)).unwrap();
                assert_eq!(a.digest, sync.digest, "{ctx}: sync reference digest");
                assert_eq!(a.server.applied_pushes, sync.server.applied_pushes, "{ctx}");
            }
        }
    }
}

#[test]
fn slow_only_plans_stretch_the_clock_but_not_the_barrier_digest() {
    // A straggler changes *when* pushes land, never *what* is applied at
    // staleness 0 — the barrier fixes the application order, so the
    // digest must match the synchronous reference with or without slow
    // faults while the virtual clock visibly stretches.
    for plan_seed in 0u64..4 {
        let c = CommConfig { compute_ms: 1.0, ..cfg(3, 0, Codec::F32) };
        let slow = FaultPlan {
            events: vec![FaultEvent::Slow {
                worker: (plan_seed as usize) % c.workers,
                from_step: 1,
                steps: 3,
                factor: 4.0 + plan_seed as f64,
            }],
            ..Default::default()
        };
        let baseline = run(&c, &FaultPlan::empty());
        let stretched = run(&c, &slow);
        let sync = run_sync_reference(&c, &store(&c)).unwrap();
        assert_eq!(stretched.digest, sync.digest, "seed {plan_seed}: digest drifted");
        assert_eq!(stretched.digest, baseline.digest, "seed {plan_seed}");
        assert!(
            stretched.virtual_secs > baseline.virtual_secs,
            "seed {plan_seed}: a {}x straggler must stretch virtual time \
             ({} !> {})",
            4.0 + plan_seed as f64,
            stretched.virtual_secs,
            baseline.virtual_secs
        );
    }
}

#[test]
fn trace_derived_pool_fracs_drive_evictions_and_recoveries() {
    // The elastic wiring: a diurnal `pool_frac` trace shrinks membership
    // in its trough and restores it on the way back up, which must show
    // up as real evictions, rejoins, and paid recovery time — and the
    // whole derived run must replay bit-identically.
    let c = CommConfig { steps: 12, ..cfg(4, 1, Codec::SparseF16) };
    let plan = FaultPlan::parse("trace:diurnal", c.workers, c.steps, c.seed).unwrap();
    assert!(!plan.is_empty(), "diurnal trough must derive kills");
    let a = run(&c, &plan);
    let b = run(&c, &plan);
    assert_bit_identical(&a, &b, "trace:diurnal");
    assert!(a.server.evictions >= 1, "trough must evict");
    assert!(a.server.joins >= 1, "ramp back up must rejoin");
    assert!(a.snapshot.recovery_secs > 0.0, "rejoin handoff must cost time");
    // A flat trace derives the empty plan and stays on the no-fault path.
    let flat = FaultPlan::parse("trace:ramp", c.workers, c.steps, c.seed).unwrap();
    assert!(flat.is_empty(), "ramp keeps pool_frac at 1.0");
    assert_eq!(run(&c, &flat).digest, run(&c, &FaultPlan::empty()).digest);
}
