//! Integration: the full scheduling story across modules — RL with the
//! HLO LSTM policy against brute force (Table 2's optimality claim),
//! the §6.2 comparison invariants, and provisioning + simulation coupling.
//!
//! RL-LSTM tests require `make artifacts` (they skip otherwise); the
//! comparison invariants run regardless via the tabular policy.

use heterps::cost::{CostConfig, CostModel};
use heterps::model::zoo;
use heterps::plan::SchedulingPlan;
use heterps::resources::{paper_testbed, simulated_types};
use heterps::runtime::artifacts_dir;
use heterps::sched::bruteforce::BruteForce;
use heterps::sched::rl::{RlConfig, RlScheduler};
use heterps::sched::{self, Scheduler, SchedulerSpec};
use heterps::simulator::{simulate_plan, SimConfig};

fn artifacts_ready() -> bool {
    artifacts_dir().join("policy_lstm_fwd.hlo.txt").exists()
}

#[test]
fn rl_lstm_hlo_matches_bruteforce_on_nce() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let model = zoo::nce();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let bf = BruteForce::new().schedule(&cm);
    let cfg = RlConfig { rounds: 40, samples_per_round: 6, ..Default::default() };
    let rl = RlScheduler::lstm(cfg, 42).schedule(&cm);
    assert!(
        rl.eval.cost_usd <= bf.eval.cost_usd * 1.01,
        "RL-LSTM {} vs BF {}",
        rl.eval.cost_usd,
        bf.eval.cost_usd
    );
}

#[test]
fn rl_lstm_scales_to_64_types_without_scheduling_blowup() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Table 3's point: RL-LSTM's scheduling time does not grow with the
    // number of resource types (the policy emits a masked 64-way softmax
    // either way).
    let model = zoo::two_emb();
    let cfg = RlConfig { rounds: 8, samples_per_round: 4, ..Default::default() };
    let pool_small = simulated_types(2, true);
    let pool_big = simulated_types(64, true);
    let cm_small = CostModel::new(&model, &pool_small, CostConfig::default());
    let cm_big = CostModel::new(&model, &pool_big, CostConfig::default());
    let t_small = RlScheduler::lstm(cfg.clone(), 1).schedule(&cm_small);
    let t_big = RlScheduler::lstm(cfg, 1).schedule(&cm_big);
    t_big.plan.validate(&model, &pool_big).unwrap();
    let ratio = t_big.wall_time.as_secs_f64() / t_small.wall_time.as_secs_f64().max(1e-9);
    assert!(ratio < 5.0, "scheduling time blew up with type count: {ratio:.1}x");
}

#[test]
fn comparison_suite_invariants_hold() {
    let model = zoo::ctrdnn();
    let pool = simulated_types(4, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let mut results = Vec::new();
    for m in sched::comparison_methods() {
        // Use the artifact-free tabular policy for RL variants here; the
        // HLO policies are covered above.
        let name = match m {
            "rl" | "rl-rnn" => "rl-tabular",
            other => other,
        };
        let mut s = SchedulerSpec::parse(name).unwrap().build(7);
        let out = s.schedule(&cm);
        out.plan.validate(&model, &pool).unwrap();
        if out.eval.feasible {
            assert!(
                out.eval.throughput >= cm.cfg.throughput_limit * 0.999,
                "{m}: feasible but under floor"
            );
        }
        results.push((m.to_string(), out));
    }
    // The searching methods must beat (or tie) CPU-only and GPU-only.
    let cost = |n: &str| {
        results
            .iter()
            .find(|(m, _)| m == n)
            .map(|(_, o)| o.eval.cost_usd)
            .unwrap()
    };
    assert!(cost("rl") <= cost("cpu"));
    assert!(cost("rl") <= cost("gpu"));
}

#[test]
fn provision_then_simulate_composes() {
    let model = zoo::matchnet();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let plan = SchedulingPlan::new(
        model
            .layers
            .iter()
            .map(|l| if l.kind.data_intensive() { 0 } else { 1 })
            .collect(),
    );
    let eval = cm.evaluate(&plan);
    if !eval.feasible {
        // Pool too small for this floor — acceptable, but the penalty
        // path must still price it.
        assert!(eval.cost_usd.is_finite());
        return;
    }
    let sim = simulate_plan(&cm, &plan, &SimConfig::default(), 3).unwrap();
    // Simulation includes overheads: somewhat slower than analytic, but
    // within a small factor (the cost model is calibrated, not fantasy).
    let ratio = eval.throughput / sim.throughput;
    assert!((1.0..8.0).contains(&ratio), "analytic/simulated throughput ratio {ratio}");
}
