//! Integration: every AOT artifact loads, compiles and executes through
//! PJRT with the shapes the rust side expects, and the policy/step
//! semantics hold end-to-end across the FFI boundary.
//!
//! Requires `make artifacts`. Tests skip (not fail) when artifacts are
//! missing so `cargo test` stays green on a fresh checkout.

use heterps::runtime::{artifacts_dir, lit, Runtime};
use heterps::sched::rl::policy::{FeatureMatrix, Policy, Sample, FEAT_DIM, L_MAX};
use heterps::util::rng::Rng;

fn artifacts_ready() -> bool {
    artifacts_dir().join("policy_lstm_fwd.hlo.txt").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn demo_features(num_layers: usize, num_types: usize) -> FeatureMatrix {
    let mut data = vec![0.0f32; L_MAX * FEAT_DIM];
    for l in 0..num_layers {
        data[l * FEAT_DIM + l] = 1.0;
        data[l * FEAT_DIM + L_MAX + (l % 8)] = 1.0;
        data[l * FEAT_DIM + L_MAX + 8] = 0.5;
        data[l * FEAT_DIM + L_MAX + 9] = 1.0;
        data[l * FEAT_DIM + L_MAX + 10] = 0.25;
    }
    FeatureMatrix { data, num_layers, num_types }
}

#[test]
fn lstm_policy_probs_are_distributions() {
    require_artifacts!();
    let mut rng = Rng::new(1);
    let mut pol = heterps::runtime::policy::HloPolicy::load_lstm(&mut rng).unwrap();
    let feats = demo_features(10, 3);
    let probs = pol.probs(&feats);
    assert_eq!(probs.len(), 10);
    for row in &probs {
        assert_eq!(row.len(), 3);
        let s: f64 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5, "row sums to {s}");
        assert!(row.iter().all(|&p| p > 0.0));
    }
}

#[test]
fn rnn_policy_probs_are_distributions() {
    require_artifacts!();
    let mut rng = Rng::new(2);
    let mut pol = heterps::runtime::policy::HloPolicy::load_rnn(&mut rng).unwrap();
    let feats = demo_features(5, 2);
    let probs = pol.probs(&feats);
    assert_eq!(probs.len(), 5);
    for row in &probs {
        let s: f64 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}

#[test]
fn lstm_step_moves_probability_toward_positive_advantage_actions() {
    require_artifacts!();
    let mut rng = Rng::new(3);
    let mut pol = heterps::runtime::policy::HloPolicy::load_lstm(&mut rng).unwrap();
    let feats = demo_features(8, 4);
    let actions: Vec<usize> = (0..8).map(|l| l % 4).collect();
    let before: f64 = pol
        .probs(&feats)
        .iter()
        .zip(&actions)
        .map(|(row, &a)| row[a].ln())
        .sum();
    for _ in 0..10 {
        pol.update(&feats, &[Sample { actions: actions.clone(), advantage: 1.0 }], 0.5);
    }
    let after: f64 = pol
        .probs(&feats)
        .iter()
        .zip(&actions)
        .map(|(row, &a)| row[a].ln())
        .sum();
    assert!(after > before, "log-prob should rise: {before} -> {after}");
}

#[test]
fn fused_step_decreases_loss_across_ffi() {
    require_artifacts!();
    let rt = Runtime::global().unwrap();
    let step = rt.load_named("ctr_fused_step").unwrap();
    let mut rng = Rng::new(4);
    use heterps::train::stage::{MB_ROWS, STAGE1_PARAMS, STAGE2_PARAMS, X_DIM};
    let p1: Vec<f32> = (0..STAGE1_PARAMS).map(|_| (rng.f32() - 0.5) * 0.05).collect();
    let p2: Vec<f32> = (0..STAGE2_PARAMS).map(|_| (rng.f32() - 0.5) * 0.05).collect();
    let x: Vec<f32> = (0..MB_ROWS * X_DIM).map(|_| (rng.f32() - 0.5) * 0.2).collect();
    let y: Vec<f32> = (0..MB_ROWS).map(|_| if rng.chance(0.3) { 1.0 } else { 0.0 }).collect();
    let out = step
        .run(&[
            lit::vec1(&p1),
            lit::vec1(&p2),
            lit::mat(&x, MB_ROWS, X_DIM).unwrap(),
            lit::vec1(&y),
            lit::scalar(0.5),
        ])
        .unwrap();
    assert_eq!(out.len(), 3);
    let loss0 = lit::to_f32s(&out[0]).unwrap()[0];
    let p1n = lit::to_f32s(&out[1]).unwrap();
    let p2n = lit::to_f32s(&out[2]).unwrap();
    assert_eq!(p1n.len(), STAGE1_PARAMS);
    assert_eq!(p2n.len(), STAGE2_PARAMS);
    let out2 = step
        .run(&[
            lit::vec1(&p1n),
            lit::vec1(&p2n),
            lit::mat(&x, MB_ROWS, X_DIM).unwrap(),
            lit::vec1(&y),
            lit::scalar(0.5),
        ])
        .unwrap();
    let loss1 = lit::to_f32s(&out2[0]).unwrap()[0];
    assert!(loss1 < loss0, "fused step should reduce loss: {loss0} -> {loss1}");
}

#[test]
fn all_declared_artifacts_load_and_compile() {
    require_artifacts!();
    let rt = Runtime::global().unwrap();
    for name in [
        "policy_lstm_fwd",
        "policy_lstm_step",
        "policy_rnn_fwd",
        "policy_rnn_step",
        "ctr_stage1_fwd",
        "ctr_stage1_bwd",
        "ctr_stage2_fwd",
        "ctr_stage2_bwd",
        "ctr_fused_step",
    ] {
        rt.load_named(name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
    }
}
