//! Integration: the pipeline trainer over real HLO stages — gradient
//! equivalence with the fused single-process step, loss descent on
//! synthetic CTR data, pipeline-vs-sync agreement, and PS coupling.
//!
//! Requires `make artifacts`; tests skip when artifacts are absent.

use heterps::data::dataset::{CtrDataset, DatasetConfig};
use heterps::runtime::{artifacts_dir, lit, Runtime};
use heterps::train::pipeline::{PipelineConfig, PipelineTrainer};
use heterps::train::stage::{
    BackwardOut, EmbeddingStage, HloStage, MicroBatch, StageOp, Tensor, EMB_DIM, MB_ROWS, SLOTS,
    X_DIM,
};
use heterps::train::sync_baseline::SyncBaselineRuntime;
use heterps::train::ParamServer;
use heterps::util::rng::Rng;
use std::sync::Arc;

fn artifacts_ready() -> bool {
    artifacts_dir().join("ctr_stage1_fwd.hlo.txt").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

/// First stage that emits a fixed dense tensor (bypasses the PS embedding
/// so the pipeline's dense math can be compared against the fused step).
struct FixedSource {
    x: Vec<f32>,
}

impl StageOp for FixedSource {
    fn name(&self) -> &str {
        "fixed-source"
    }
    fn forward(&mut self, mb: &MicroBatch, input: Option<&Tensor>) -> anyhow::Result<Tensor> {
        assert!(input.is_none());
        let rows = mb.labels.len();
        Ok(Tensor::from_vec(self.x.clone(), rows, X_DIM))
    }
    fn backward(
        &mut self,
        _mb: &MicroBatch,
        _input: Option<&Tensor>,
        _grad: Option<&Tensor>,
    ) -> anyhow::Result<BackwardOut> {
        Ok(BackwardOut { dinput: None, loss: None })
    }
    fn dense_grads_mut(&mut self) -> Option<&mut Vec<f32>> {
        None
    }
    fn apply_update(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
    fn set_speed_factor(&mut self, _f: f64) {}
}

fn demo_mb(seed: u64) -> (MicroBatch, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..MB_ROWS * X_DIM).map(|_| (rng.f32() - 0.5) * 0.2).collect();
    let labels: Vec<f32> = (0..MB_ROWS).map(|_| if rng.chance(0.3) { 1.0 } else { 0.0 }).collect();
    (MicroBatch { index: 0, sparse_ids: vec![0; MB_ROWS * SLOTS], labels }, x)
}

#[test]
fn pipeline_gradients_match_fused_step() {
    require_artifacts!();
    let (mb, x) = demo_mb(11);
    let lr = 0.25f32;

    // Pipeline: source -> stage1 -> stage2(loss), one microbatch.
    let s1 = HloStage::ctr_stage1(lr, 101).unwrap();
    let s2 = HloStage::ctr_stage2(lr, 202).unwrap();
    let p1_init = s1.params.clone();
    let p2_init = s2.params.clone();
    let mut trainer = PipelineTrainer::new(
        vec![Box::new(FixedSource { x: x.clone() }), Box::new(s1), Box::new(s2)],
        PipelineConfig { microbatches: 1 },
    );
    let pipe_loss = trainer.train_step(std::slice::from_ref(&mb)).unwrap();

    // Fused oracle on identical inputs.
    let rt = Runtime::global().unwrap();
    let step = rt.load_named("ctr_fused_step").unwrap();
    let out = step
        .run(&[
            lit::vec1(&p1_init),
            lit::vec1(&p2_init),
            lit::mat(&x, MB_ROWS, X_DIM).unwrap(),
            lit::vec1(&mb.labels),
            lit::scalar(lr),
        ])
        .unwrap();
    let fused_loss = lit::to_f32s(&out[0]).unwrap()[0];
    let p1_fused = lit::to_f32s(&out[1]).unwrap();
    let p2_fused = lit::to_f32s(&out[2]).unwrap();

    assert!((pipe_loss - fused_loss).abs() < 1e-4, "loss {pipe_loss} vs fused {fused_loss}");

    // Updated parameters agree functionally: the pipeline's post-update
    // stage-1 forward must equal the fused post-update forward on the same
    // input (same gradients + same SGD step => same weights).
    let err = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    };
    let mut t = trainer;
    let s1f = rt.load_named("ctr_stage1_fwd").unwrap();
    let y_fused = s1f
        .run1(&[lit::vec1(&p1_fused), lit::mat(&x, MB_ROWS, X_DIM).unwrap()])
        .unwrap();
    let y_fused = lit::to_f32s(&y_fused).unwrap();
    let y_pipe = t.stages_mut()[1]
        .forward(&mb, Some(&Tensor::from_vec(x.clone(), MB_ROWS, X_DIM)))
        .unwrap();
    assert!(
        err(&y_pipe.data, &y_fused) < 1e-3,
        "post-update stage1 outputs diverge by {}",
        err(&y_pipe.data, &y_fused)
    );
    let _ = p2_fused;
}

#[test]
fn full_pipeline_with_ps_embedding_reduces_loss() {
    require_artifacts!();
    let ps = Arc::new(ParamServer::new(EMB_DIM, 16, 0.5, 7));
    let mut trainer = PipelineTrainer::new(
        vec![
            Box::new(EmbeddingStage::new(ps.clone())),
            Box::new(HloStage::ctr_stage1(0.25, 31).unwrap()),
            Box::new(HloStage::ctr_stage2(0.25, 32).unwrap()),
        ],
        PipelineConfig { microbatches: 2 },
    );
    let mut ds = CtrDataset::new(
        DatasetConfig { slots: SLOTS, vocab: 5_000, ..Default::default() },
        13,
    );
    let mut first = None;
    let mut last = 0.0;
    for step in 0..12 {
        let batch = ds.next_batch(2 * MB_ROWS);
        let mbs = PipelineTrainer::microbatches(&batch, SLOTS);
        let loss = trainer.train_step(&mbs).unwrap();
        if step == 0 {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    assert!(last < first, "loss should fall: {first} -> {last}");
    assert!(ps.rows() > 0, "PS must have materialized embedding rows");
    assert!(ps.push_count() > 0, "sparse gradients must flow to the PS");
}

#[test]
fn sync_baseline_computes_identical_loss_math() {
    require_artifacts!();
    let (mb, x) = demo_mb(17);
    let mk = |seed1, seed2| -> Vec<Box<dyn StageOp>> {
        vec![
            Box::new(FixedSource { x: x.clone() }),
            Box::new(HloStage::ctr_stage1(0.1, seed1).unwrap()),
            Box::new(HloStage::ctr_stage2(0.1, seed2).unwrap()),
        ]
    };
    let mut pipe = PipelineTrainer::new(mk(51, 52), PipelineConfig { microbatches: 1 });
    let mut sync = SyncBaselineRuntime::new(mk(51, 52));
    let lp = pipe.train_step(std::slice::from_ref(&mb)).unwrap();
    let ls = sync.train_step(std::slice::from_ref(&mb)).unwrap();
    assert!((lp - ls).abs() < 1e-5, "pipeline {lp} vs sync {ls}");
}
