//! Integration tests for the budgeted `SearchSession` API and the typed
//! `SchedulerSpec` registry: spec round-trips on every registered method,
//! session == `schedule()` determinism, budget/deadline/target
//! enforcement, zero-budget degradation and warm-start rescheduling.

use heterps::config::Config;
use heterps::cost::{CostConfig, CostModel};
use heterps::model::zoo;
use heterps::plan::SchedulingPlan;
use heterps::resources::{paper_testbed, simulated_types};
use heterps::sched::{self, registry, Budget, ScheduleError, SchedulerSpec};
use std::time::Duration;

/// Cap on manual stepping: far above any session's real step count, only
/// here so a broken session cannot hang the suite.
const STEP_CAP: usize = 1_000_000;

#[test]
fn spec_string_round_trips_for_every_registered_method() {
    for info in registry() {
        let spec = SchedulerSpec::parse(info.canonical)
            .unwrap_or_else(|e| panic!("{}: {e}", info.canonical));
        assert_eq!(spec.method(), info.canonical);
        let shown = spec.to_string();
        assert_eq!(
            SchedulerSpec::parse(&shown).unwrap(),
            spec,
            "`{shown}` did not round-trip"
        );
        for alias in info.aliases {
            assert_eq!(SchedulerSpec::parse(alias).unwrap(), spec, "alias {alias}");
        }
    }
}

#[test]
fn spec_toml_round_trips_for_every_registered_method() {
    for info in registry() {
        let spec = SchedulerSpec::parse(info.canonical).unwrap();
        let toml = spec.to_toml();
        let cfg = Config::parse(&toml).unwrap_or_else(|e| panic!("{toml}: {e}"));
        let back = SchedulerSpec::from_config(&cfg)
            .unwrap_or_else(|e| panic!("{}: {e}", info.canonical))
            .expect("section present");
        assert_eq!(back, spec, "TOML round-trip for {}", info.canonical);
    }
}

#[test]
fn toml_scheduler_section_applies_typed_options() {
    let cfg = Config::parse(
        "[scheduler]\nmethod = \"rl\"\nrounds = 80\nlr = 0.6\n",
    )
    .unwrap();
    let spec = SchedulerSpec::from_config(&cfg).unwrap().unwrap();
    assert_eq!(spec, SchedulerSpec::parse("rl:rounds=80,lr=0.6").unwrap());
}

#[test]
fn comparison_methods_are_registry_backed() {
    let methods = sched::comparison_methods();
    assert_eq!(
        methods,
        vec!["rl", "rl-rnn", "bo", "genetic", "greedy", "gpu", "cpu", "heuristic"]
    );
    for m in methods {
        assert!(sched::lookup(m).is_some(), "{m} missing from registry");
    }
}

/// The acceptance bar of the redesign: for seeds {1, 42} on `ctrdnn` +
/// `paper_testbed`, manually stepping an unbudgeted session produces the
/// exact plan and evaluation count of the `schedule()` convenience
/// wrapper, for every registered method (all six scheduler families).
#[test]
fn unbudgeted_session_reproduces_schedule_for_all_methods() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for seed in [1u64, 42] {
        for info in registry() {
            let spec = SchedulerSpec::parse(info.canonical).unwrap();
            let one_shot = spec.build(seed).schedule(&cm);

            let scheduler = spec.build(seed);
            let mut session = scheduler.session(&cm, Budget::unlimited());
            let mut steps = 0usize;
            while !session.step().converged {
                steps += 1;
                assert!(steps < STEP_CAP, "{} never converged", info.canonical);
            }
            let stepped = session.outcome().unwrap();

            assert_eq!(
                stepped.plan, one_shot.plan,
                "{} seed {seed}: session plan != schedule() plan",
                info.canonical
            );
            assert_eq!(
                stepped.evaluations, one_shot.evaluations,
                "{} seed {seed}: evaluation counts differ",
                info.canonical
            );
            assert!(
                (stepped.eval.cost_usd - one_shot.eval.cost_usd).abs() < 1e-12,
                "{} seed {seed}: costs differ",
                info.canonical
            );
        }
    }
}

#[test]
fn eval_budget_is_never_exceeded_by_any_method() {
    let model = zoo::ctrdnn();
    let pool = simulated_types(4, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for info in registry() {
        let spec = SchedulerSpec::parse(info.canonical).unwrap();
        let scheduler = spec.build(7);
        for cap in [1usize, 2, 10, 57] {
            let mut session = scheduler.session(&cm, Budget::evals(cap));
            let mut steps = 0usize;
            loop {
                let report = session.step();
                assert!(
                    report.evaluations <= cap,
                    "{} exceeded budget {cap}: {}",
                    info.canonical,
                    report.evaluations
                );
                if report.converged {
                    break;
                }
                steps += 1;
                assert!(steps < STEP_CAP);
            }
            // Every method evaluates at least one plan given any budget.
            let out = session.outcome().unwrap_or_else(|e| {
                panic!("{} with budget {cap}: {e}", info.canonical)
            });
            assert!(out.evaluations >= 1 && out.evaluations <= cap);
        }
    }
}

#[test]
fn zero_eval_budget_degrades_gracefully() {
    let model = zoo::nce();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    for info in registry() {
        let scheduler = SchedulerSpec::parse(info.canonical).unwrap().build(3);
        let mut session = scheduler.session(&cm, Budget::evals(0));
        let result = sched::drive(session.as_mut(), None);
        assert!(
            matches!(result, Err(ScheduleError::NoPlansEvaluated)),
            "{} should report NoPlansEvaluated on a zero budget",
            info.canonical
        );
        assert_eq!(session.evaluations(), 0, "{}", info.canonical);
        assert!(session.report().budget_exhausted, "{}", info.canonical);
    }
}

#[test]
fn expired_deadline_stops_before_any_evaluation() {
    let model = zoo::nce();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let scheduler = SchedulerSpec::parse("genetic").unwrap().build(3);
    let mut session =
        scheduler.session(&cm, Budget::unlimited().with_deadline(Duration::ZERO));
    assert!(matches!(
        sched::drive(session.as_mut(), None),
        Err(ScheduleError::NoPlansEvaluated)
    ));
    assert_eq!(session.evaluations(), 0);
    // A generous deadline changes nothing about a fast search.
    let mut session = scheduler
        .session(&cm, Budget::unlimited().with_deadline(Duration::from_secs(3600)));
    let out = sched::drive(session.as_mut(), None).unwrap();
    assert!(out.evaluations >= 1);
}

#[test]
fn target_cost_stops_the_search_early() {
    let model = zoo::nce(); // 5 layers, so BF enumerates 2^5 = 32 plans
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    // Replicate BF's odometer order (layer 0 is least significant) to find
    // where the first feasible plan sits in the enumeration.
    let nl = model.num_layers();
    let first_feasible = (0..32u32).find(|code| {
        let a: Vec<usize> = (0..nl).map(|l| ((code >> l) & 1) as usize).collect();
        cm.evaluate(&SchedulingPlan::new(a)).feasible
    });
    // An infinite target accepts the first feasible incumbent.
    let scheduler = SchedulerSpec::parse("bf").unwrap().build(1);
    let mut session =
        scheduler.session(&cm, Budget::unlimited().with_target_cost(f64::INFINITY));
    let out = sched::drive(session.as_mut(), None).unwrap();
    match first_feasible {
        Some(f) => assert_eq!(out.evaluations, f as usize + 1),
        None => assert_eq!(out.evaluations, 32),
    }
}

#[test]
fn progress_observer_sees_every_step() {
    let model = zoo::nce();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let scheduler = SchedulerSpec::parse("greedy").unwrap().build(1);
    let mut session = scheduler.session(&cm, Budget::unlimited());
    let mut reports = Vec::new();
    let mut observer = |r: &sched::StepReport| reports.push(r.evaluations);
    let out = sched::drive(session.as_mut(), Some(&mut observer)).unwrap();
    // Greedy on 5 layers: 1 init step + 5 sweep steps.
    assert_eq!(reports.len(), 6);
    assert_eq!(*reports.last().unwrap(), out.evaluations);
    // Evaluation counts are monotone across steps.
    assert!(reports.windows(2).all(|w| w[0] <= w[1]));
}

#[test]
fn warm_start_seeds_and_never_worsens_the_incumbent() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let warm_plan = SchedulingPlan::new(
        model.layers.iter().map(|l| if l.kind.data_intensive() { 0 } else { 1 }).collect(),
    );
    let warm_eval = cm.evaluate(&warm_plan);

    // Budget 1: only the warm-start evaluation fits, so it IS the outcome.
    let scheduler = SchedulerSpec::parse("genetic").unwrap().build(11);
    let mut session = scheduler.session(&cm, Budget::evals(1));
    session.warm_start(&warm_plan);
    let out = sched::drive(session.as_mut(), None).unwrap();
    assert_eq!(out.plan, warm_plan);
    assert_eq!(out.evaluations, 1);

    // With room to search, the reschedule can only improve on the warm
    // plan (feasibility first, then cost — BestTracker's ordering).
    let mut session = scheduler.session(&cm, Budget::evals(200));
    session.warm_start(&warm_plan);
    let out = sched::drive(session.as_mut(), None).unwrap();
    if warm_eval.feasible {
        assert!(out.eval.feasible);
        assert!(out.eval.cost_usd <= warm_eval.cost_usd * (1.0 + 1e-9));
    }
}

#[test]
fn warm_start_carries_plans_across_an_elastic_pool_change() {
    // The elastic-provisioning story: schedule on a small pool, the pool
    // grows, reschedule incrementally from the old plan under a budget.
    let model = zoo::ctrdnn();
    let small = simulated_types(2, true);
    let big = simulated_types(4, true);
    let cm_small = CostModel::new(&model, &small, CostConfig::default());
    let cm_big = CostModel::new(&model, &big, CostConfig::default());

    let spec = SchedulerSpec::parse("rl-tabular").unwrap();
    let old = spec.build(42).schedule(&cm_small);
    // Type ids of the small pool remain valid in the grown pool.
    old.plan.validate(&model, &big).unwrap();

    let scheduler = spec.build(42);
    let mut session = scheduler.session(&cm_big, Budget::evals(150));
    session.warm_start(&old.plan);
    let out = sched::drive(session.as_mut(), None).unwrap();
    assert!(out.evaluations <= 150);
    let old_on_big = cm_big.evaluate(&old.plan);
    if old_on_big.feasible {
        assert!(out.eval.feasible);
        assert!(out.eval.cost_usd <= old_on_big.cost_usd * (1.0 + 1e-9));
    }
}
