//! Cross-backend integration tests for the async communication fabric:
//! the SSP engine must behave identically over the in-memory
//! [`ParamServer`] and the disk-tiered [`TieredParamServer`], stay
//! deadlock-free at high worker counts, and honor the staleness-0
//! bit-for-bit contract end to end.

use heterps::comm::{run_async, run_sync_reference, CommConfig};
use heterps::data::compress::Codec;
use heterps::resources::paper_testbed;
use heterps::train::{ParamServer, TieredParamServer};

fn cfg(workers: usize, staleness: u64, codec: Codec) -> CommConfig {
    CommConfig {
        workers,
        steps: 5,
        rows: 8,
        slots: 4,
        dim: 8,
        vocab: 256,
        staleness,
        codec,
        compute_ms: 0.0,
        seed: 42,
        ..Default::default()
    }
}

fn flat(c: &CommConfig) -> ParamServer {
    ParamServer::new(c.dim, 8, 0.3, c.seed)
}

fn tiered(c: &CommConfig, hot: usize) -> TieredParamServer {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "heterps-comm-it-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    TieredParamServer::new(dir, c.dim, hot, 0.3, c.seed).expect("tiered store")
}

#[test]
fn tiered_and_flat_backends_agree_bit_for_bit_at_staleness_zero() {
    let pool = paper_testbed();
    let c = cfg(3, 0, Codec::F16);
    let flat_store = flat(&c);
    let flat_run = run_async(&c, &pool, &flat_store).unwrap();
    // A hot budget far below the touched row count forces constant spill
    // during the run; the fabric must not notice.
    let tiered_store = tiered(&c, 16);
    let tiered_run = run_async(&c, &pool, &tiered_store).unwrap();
    assert_eq!(flat_run.digest, tiered_run.digest, "backends diverged");
    // And both match the single-threaded synchronous reference.
    let sync = run_sync_reference(&c, &flat(&c)).unwrap();
    assert_eq!(flat_run.digest, sync.digest);
}

#[test]
fn sync_reference_is_backend_independent() {
    let c = cfg(2, 0, Codec::SparseF16);
    let a = run_sync_reference(&c, &flat(&c)).unwrap();
    let b = run_sync_reference(&c, &tiered(&c, 8)).unwrap();
    assert_eq!(a.digest, b.digest);
    assert_eq!(a.server, b.server);
}

#[test]
fn eight_workers_complete_at_every_staleness_without_deadlock() {
    let pool = paper_testbed();
    for staleness in [0u64, 1, 4] {
        for codec in [Codec::F32, Codec::SparseF16] {
            let c = cfg(8, staleness, codec);
            let store = flat(&c);
            let r = run_async(&c, &pool, &store).unwrap();
            assert_eq!(r.server.applied_pushes, (c.workers * c.steps) as u64);
            assert_eq!(r.server.served_pulls, (c.workers * c.steps) as u64);
            assert!(r.snapshot.staleness_max <= staleness);
            if staleness == 0 {
                let sync = run_sync_reference(&c, &flat(&c)).unwrap();
                assert_eq!(r.digest, sync.digest, "codec {codec:?}");
            }
        }
    }
}

#[test]
fn distinct_seeds_produce_distinct_tables() {
    let pool = paper_testbed();
    let a_cfg = cfg(2, 0, Codec::F32);
    let b_cfg = CommConfig { seed: 43, ..a_cfg.clone() };
    let a = run_async(&a_cfg, &pool, &flat(&a_cfg)).unwrap();
    let b = run_async(&b_cfg, &pool, &flat(&b_cfg)).unwrap();
    assert_ne!(a.digest, b.digest, "seed must perturb the workload");
}
