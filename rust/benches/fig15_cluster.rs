//! Figure 15 (ours, beyond the paper): multi-tenant cluster scheduling.
//! For every bundled job mix and a spread of per-job scheduler methods,
//! replay the mix under the three allocation policies (fifo, srtf,
//! drf-cost) and compare mean JCT, queueing delay, SLA damage, makespan
//! and cumulative dollars. Expected shape: on the contention-shaped
//! `tight` mix, FIFO's head-of-line blocking starves the short jobs
//! behind the blocked big one, so both `srtf` (which also preempts the
//! long incumbent) and `drf-cost` (which admits small-share tenants
//! around the blockage) strictly beat it on mean JCT — asserted below.

use heterps::cluster::{self, ClusterConfig, ClusterReport};
use heterps::metrics::Table;
use heterps::resources::simulated_types;
use heterps::sched::SchedulerSpec;

fn main() {
    let seed = 42u64;
    let base_floor = 20_000.0;
    let jobs = 6;

    let mut columns = vec!["mix", "method"];
    columns.extend_from_slice(&ClusterReport::SUMMARY_COLUMNS);
    let mut table = Table::new(
        "Figure 15 — multi-tenant cluster: policy comparison per job mix and method",
        &columns,
    );

    let mut tight_greedy: Option<Vec<ClusterReport>> = None;
    for mix_name in cluster::mix_names() {
        let pool = match *mix_name {
            "tight" => cluster::tight_pool(),
            _ => simulated_types(2, true),
        };
        let queue = cluster::mix_by_name(mix_name, jobs, seed, base_floor).unwrap();
        // Artifact-free methods only, so the bench runs without
        // `make artifacts` (like the elastic example).
        for spec_str in ["greedy", "genetic", "rl-tabular:rounds=20"] {
            let cfg = ClusterConfig {
                spec: SchedulerSpec::parse(spec_str).unwrap(),
                ..Default::default()
            };
            let reports = cluster::run_all_policies(&pool, &queue, &cfg, seed)
                .unwrap_or_else(|e| panic!("{mix_name}/{spec_str}: {e}"));
            for r in &reports {
                let mut row = vec![mix_name.to_string(), spec_str.to_string()];
                row.extend(r.summary_row());
                table.row(&row);
            }
            if *mix_name == "tight" && spec_str == "greedy" {
                tight_greedy = Some(reports);
            }
        }
    }
    table.emit("fig15_cluster");

    // The acceptance shape: on the tight mix, srtf and drf-cost must each
    // strictly beat fifo on mean JCT or cumulative dollars.
    let reports = tight_greedy.expect("tight/greedy ran");
    let by_name = |n: &str| reports.iter().find(|r| r.policy == n).unwrap();
    let (fifo, srtf, drf) = (by_name("fifo"), by_name("srtf"), by_name("drf-cost"));
    for challenger in [srtf, drf] {
        assert!(
            challenger.mean_jct_secs() < fifo.mean_jct_secs()
                || challenger.cumulative_cost_usd < fifo.cumulative_cost_usd,
            "{} (JCT {:.0} s, ${:.2}) does not beat fifo (JCT {:.0} s, ${:.2})",
            challenger.policy,
            challenger.mean_jct_secs(),
            challenger.cumulative_cost_usd,
            fifo.mean_jct_secs(),
            fifo.cumulative_cost_usd
        );
    }
    println!(
        "[fig15] tight/greedy mean JCT: fifo {:.0} s, srtf {:.0} s, drf-cost {:.0} s",
        fifo.mean_jct_secs(),
        srtf.mean_jct_secs(),
        drf.mean_jct_secs()
    );

    // Preemption is not free: every srtf pause ships the job's parameter
    // state off the freed units and back on re-admission, priced from
    // weight bytes over the plan's slowest link — so srtf's JCT win above
    // is *net* of a real checkpoint/restore bill.
    let srtf_preemptions: usize = srtf.jobs.iter().map(|j| j.preemptions).sum();
    let srtf_ckpt_secs: f64 = srtf.jobs.iter().map(|j| j.ckpt_restore_secs).sum();
    assert!(
        srtf_preemptions > 0 && srtf_ckpt_secs > 0.0,
        "tight/greedy srtf should preempt and pay a nonzero checkpoint/restore cost \
         (got {srtf_preemptions} preemptions, {srtf_ckpt_secs:.3} s)"
    );
    println!(
        "[fig15] srtf ckpt/restore bill: {srtf_ckpt_secs:.1} s across {srtf_preemptions} preemptions"
    );

    // Online calibration: rerun tight/greedy srtf with the ledger-derived
    // preemption margin (observed residual spread, capped at the stock
    // 1.25 knob). Deriving the margin from measurements must not cost
    // anything — no worse than the stock run on mean JCT or dollars.
    let pool = cluster::tight_pool();
    let queue = cluster::mix_by_name("tight", jobs, seed, base_floor).unwrap();
    let policy = cluster::policy_by_name("srtf", &pool).unwrap();
    let cfg = ClusterConfig {
        spec: SchedulerSpec::parse("greedy").unwrap(),
        calibrate_online: true,
        ..Default::default()
    };
    let derived = cluster::run_cluster(&pool, &queue, policy.as_ref(), &cfg, seed)
        .expect("tight/greedy srtf with online calibration");
    assert!(
        derived.mean_jct_secs() <= srtf.mean_jct_secs() * (1.0 + 1e-9)
            || derived.cumulative_cost_usd <= srtf.cumulative_cost_usd * (1.0 + 1e-9),
        "derived margin (JCT {:.0} s, ${:.2}) worse than the stock 1.25 knob \
         (JCT {:.0} s, ${:.2}) on both axes",
        derived.mean_jct_secs(),
        derived.cumulative_cost_usd,
        srtf.mean_jct_secs(),
        srtf.cumulative_cost_usd
    );
    println!(
        "[fig15] srtf derived margin: JCT {:.0} s vs {:.0} s stock, ${:.2} vs ${:.2} stock",
        derived.mean_jct_secs(),
        srtf.mean_jct_secs(),
        derived.cumulative_cost_usd,
        srtf.cumulative_cost_usd
    );
}
