//! Figure 5: normalized training cost per scheduling method as the number
//! of resource types grows (1–16, 32, 64), CPU included. MATCHNET profile,
//! as in §6.2. Expected shape: RL lowest everywhere; CPU-only worst; the
//! gap widens as the catalog grows (RL exploits the price-performance
//! frontier, heuristics can't).

mod common;

use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;

fn main() {
    let include_cpu = true;
    let name = "fig05_cost_types";
    let title = "Figure 5 — normalized cost vs #types (with CPU)";
    let model = zoo::matchnet();
    let mut columns = vec!["types"];
    columns.extend(common::methods());
    let mut table = Table::new(title, &columns);
    for types in [1usize, 2, 4, 8, 16, 32, 64] {
        if !include_cpu && types == 1 {
            continue; // a 1-type pool without CPU equals GPU-only everywhere
        }
        let pool = simulated_types(types, include_cpu);
        let mut costs = Vec::new();
        for method in common::methods() {
            let out = common::run_method(method, &model, &pool, 20_000.0, 42);
            costs.push(if out.eval.feasible { out.eval.cost_usd } else { f64::NAN });
        }
        let valid: Vec<f64> = costs.iter().cloned().filter(|c| c.is_finite()).collect();
        let norm = common::normalize(&valid);
        let mut it = norm.into_iter();
        let mut cells = vec![types.to_string()];
        for c in &costs {
            cells.push(if c.is_finite() {
                format!("{:.2}", it.next().unwrap())
            } else {
                "inf".into() // infeasible (pool limit), as in Fig 10's CPU bar
            });
        }
        table.row(&cells);
    }
    table.emit(name);
}
