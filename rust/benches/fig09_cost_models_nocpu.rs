//! Figure 9: the Figure-8 per-model comparison without CPU types.

mod common;

use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;

fn main() {
    let mut columns = vec!["model"];
    columns.extend(common::methods());
    let mut table = Table::new("Figure 9 — normalized cost per model (no CPU)", &columns);
    for model_name in ["matchnet", "ctrdnn", "2emb", "nce"] {
        let model = zoo::by_name(model_name).unwrap();
        let pool = simulated_types(4, false);
        let mut costs = Vec::new();
        for method in common::methods() {
            let out = common::run_method(method, &model, &pool, 20_000.0, 42);
            costs.push(if out.eval.feasible { out.eval.cost_usd } else { f64::NAN });
        }
        let valid: Vec<f64> = costs.iter().cloned().filter(|c| c.is_finite()).collect();
        let norm = common::normalize(&valid);
        let mut it = norm.into_iter();
        let mut cells = vec![model_name.to_string()];
        for c in &costs {
            cells.push(if c.is_finite() { format!("{:.2}", it.next().unwrap()) } else { "inf".into() });
        }
        table.row(&cells);
    }
    table.emit("fig09_cost_models_nocpu");
}
