//! Calibration figure (ours, beyond the paper): close the
//! analytic-vs-measured gap and show it pays. Three panels:
//!
//! 1. **Residual sweep** — plans from the artifact-free comparison
//!    methods replayed on the discrete-event simulator across seeds; the
//!    ledger's mean |log residual| before and after fitting. The fit's
//!    median guard means the calibrated residual can never be worse, and
//!    the simulator's systematic overheads (stragglers, dispatch) mean it
//!    must be strictly better — asserted.
//! 2. **Per-type scales** — the fitted [calibration] overlay itself.
//! 3. **Plan quality at a fixed eval budget** — every method searches
//!    once under the identity overlay and once under the fitted one, same
//!    budget; both final plans are replayed on the *same* simulator
//!    instrument (identity model, same seed). A calibrated reward signal
//!    tracks the instrument better, so the best measured cost must not
//!    degrade (a 10% guard absorbs stochastic search landscapes).

use heterps::calib::{CostTerm, ResidualLedger};
use heterps::cost::{CostConfig, CostModel};
use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::plan::canonical_split_plan;
use heterps::resources::simulated_types;
use heterps::sched::{self, Budget, SchedulerSpec};
use heterps::simulator::{simulate_plan, SimConfig};

const METHODS: [&str; 3] = ["greedy", "genetic", "rl-tabular:rounds=20"];
const SWEEP_SEEDS: u64 = 4;
const BUDGET_EVALS: usize = 96;

fn best_plan(cm: &CostModel, seed: u64, spec_str: &str) -> heterps::plan::SchedulingPlan {
    let spec = SchedulerSpec::parse(spec_str).unwrap();
    let scheduler = spec.build(seed);
    let engine = sched::EvalEngine::new(cm);
    let mut budget = Budget::unlimited();
    budget.max_evaluations = Some(BUDGET_EVALS);
    let mut session = scheduler.session_engine(engine, budget);
    sched::drive(session.as_mut(), None).unwrap_or_else(|e| panic!("{spec_str}: {e}")).plan
}

fn main() {
    let seed = 42u64;
    let model = zoo::by_name("ctrdnn").unwrap();
    let pool = simulated_types(4, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let simcfg = SimConfig::default();

    // Panel 1: the measurement sweep and the residual it leaves.
    let mut plans: Vec<_> = METHODS.iter().map(|m| best_plan(&cm, seed, m)).collect();
    if let Some(split) = canonical_split_plan(&model, &pool) {
        plans.push(split);
    }
    let mut seen = std::collections::BTreeSet::new();
    plans.retain(|p| seen.insert(p.render()));

    let mut ledger = ResidualLedger::new();
    for (i, p) in plans.iter().enumerate() {
        for s in 0..SWEEP_SEEDS {
            let sim_seed = seed ^ ((i as u64 + 1) << 32) ^ s;
            if let Some(sim) = simulate_plan(&cm, p, &simcfg, sim_seed) {
                ledger.record_sim(&sim);
            }
        }
    }
    assert!(!ledger.is_empty(), "no sweep plan provisioned — nothing measured");
    let before = ledger.mean_abs_log_residual();
    let calib = ledger.fit(pool.num_types(), 1);
    let after = ledger.mean_abs_log_residual_under(&calib);
    assert!(
        after < before,
        "fitting on systematically biased measurements must strictly shrink \
         the residual ({before:.4} -> {after:.4})"
    );
    println!(
        "[fig_calib] {} plans x {SWEEP_SEEDS} seeds, {} residuals: \
         mean |log residual| {before:.4} -> {after:.4}",
        plans.len(),
        ledger.len()
    );

    // Panel 2: the overlay itself.
    let headers: Vec<String> = std::iter::once("term".to_string())
        .chain(pool.types.iter().map(|t| t.name.clone()))
        .collect();
    let headers: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        format!("Calibration — fitted scales (epoch {})", calib.epoch()),
        &headers,
    );
    for term in CostTerm::ALL {
        let mut row = vec![term.name().to_string()];
        for ty in 0..pool.num_types() {
            row.push(format!("{:.3}", calib.scale(term, ty)));
        }
        t.row(&row);
    }
    t.emit("fig_calib_scales");

    // Panel 3: does the calibrated reward pick better plans at the same
    // budget? Measure both choices on the identity instrument.
    let cm_cal = CostModel::with_calibration(&model, &pool, CostConfig::default(), calib);
    let mut t = Table::new(
        "Calibration — measured plan cost at a fixed eval budget",
        &["method", "identity $ (sim)", "calibrated $ (sim)", "feasible id/cal"],
    );
    let mut best_uncal = f64::INFINITY;
    let mut best_cal = f64::INFINITY;
    for m in METHODS {
        let p_id = best_plan(&cm, seed, m);
        let p_cal = best_plan(&cm_cal, seed, m);
        let sim_id = simulate_plan(&cm, &p_id, &simcfg, seed).expect("identity plan provisions");
        let sim_cal =
            simulate_plan(&cm, &p_cal, &simcfg, seed).expect("calibrated plan provisions");
        best_uncal = best_uncal.min(sim_id.cost_usd);
        best_cal = best_cal.min(sim_cal.cost_usd);
        t.row(&[
            m.to_string(),
            format!("{:.2}", sim_id.cost_usd),
            format!("{:.2}", sim_cal.cost_usd),
            format!(
                "{}/{}",
                sim_id.throughput >= cm.cfg.throughput_limit,
                sim_cal.throughput >= cm.cfg.throughput_limit
            ),
        ]);
    }
    t.emit("fig_calib_quality");
    assert!(
        best_cal <= best_uncal * 1.10,
        "calibrated search degraded measured plan cost: best ${best_cal:.2} vs ${best_uncal:.2}"
    );
    println!(
        "[fig_calib] best measured cost at {BUDGET_EVALS} evals: \
         identity ${best_uncal:.2}, calibrated ${best_cal:.2}"
    );
}
