//! Table 3: scheduling time (s) of every method on every model, including
//! the 32- and 64-type MATCHNET rows. The paper's shape: RL-LSTM in the
//! tens of seconds (flat in the type count), RL-RNN slower, BO slowest of
//! the learned methods, Genetic tens of seconds, Greedy/GPU/CPU/Heuristic
//! effectively instant.
//!
//! A second table reports the anytime view the session API enables: each
//! method's incumbent cost after 10 / 100 / 1k cost-model evaluations —
//! the per-budget rows of the cost-under-a-scheduling-time-budget story.

mod common;

use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;
use heterps::util::fmt_secs;

const MILESTONES: [usize; 3] = [10, 100, 1000];

fn main() {
    let rows: Vec<(&str, &str, usize)> = vec![
        ("MATCHNET", "matchnet", 2),
        ("MATCHNET (32)", "matchnet", 32),
        ("MATCHNET (64)", "matchnet", 64),
        ("CTRDNN", "ctrdnn", 2),
        ("2EMB", "2emb", 2),
        ("NCE", "nce", 2),
    ];
    let methods = common::methods();
    let mut columns = vec!["model"];
    columns.extend(methods.iter().copied());
    let mut table = Table::new("Table 3 — scheduling time (s) per method", &columns);
    let mut anytime = Table::new(
        "Table 3b — incumbent cost ($) at 10/100/1k evaluations",
        &columns,
    );

    // Warm the PJRT executable cache (one-time policy compilation) so the
    // first row's RL timings are comparable to the rest.
    {
        let model = zoo::nce();
        let pool = simulated_types(2, true);
        for method in ["rl", "rl-rnn"] {
            let _ = common::run_method(method, &model, &pool, 20_000.0, 1);
        }
    }

    for (label, model_name, types) in rows {
        let model = zoo::by_name(model_name).unwrap();
        let pool = simulated_types(types, true);
        let mut cells = vec![label.to_string()];
        let mut budget_cells = vec![label.to_string()];
        for method in &methods {
            let out = common::run_method(method, &model, &pool, 20_000.0, 42);
            cells.push(fmt_secs(out.wall_time.as_secs_f64()));
            let curve =
                common::anytime_costs(method, &model, &pool, 20_000.0, 42, &MILESTONES);
            budget_cells.push(common::fmt_curve(&curve));
        }
        table.row(&cells);
        anytime.row(&budget_cells);
    }
    table.emit("table3_sched_time");
    anytime.emit("table3_anytime");
}
