//! Ablation: the §5.2 design choices inside the RL scheduler.
//!
//! * policy architecture — LSTM (ours) vs Elman RNN vs per-layer tabular
//!   logits (no inter-layer awareness at all): quantifies the paper's
//!   claim that the LSTM "can well capture the influence of the
//!   scheduling decisions of different layers".
//! * baseline subtraction (Eq 15) — REINFORCE with vs without the
//!   moving-average baseline: the variance-reduction ablation.
//!
//! Metric: best feasible cost found under an equal sampling budget
//! (median over seeds), plus scheduling time.

mod common;

use heterps::cost::{CostConfig, CostModel};
use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;
use heterps::sched::rl::{RlConfig, RlScheduler};
use heterps::sched::Scheduler;
use heterps::util::stats::median;

fn run(mk: &dyn Fn(u64) -> RlScheduler, cm: &CostModel, seeds: &[u64]) -> (f64, f64) {
    let mut costs = Vec::new();
    let mut times = Vec::new();
    for &seed in seeds {
        let out = mk(seed).schedule(cm);
        costs.push(out.eval.cost_usd);
        times.push(out.wall_time.as_secs_f64());
    }
    (median(&costs), median(&times))
}

fn main() {
    let model = zoo::matchnet();
    let pool = simulated_types(8, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let seeds = [1u64, 2, 3];
    let budget = RlConfig { rounds: 40, samples_per_round: 8, ..Default::default() };
    let no_baseline = RlConfig { baseline_gamma: 1e-9, ..budget.clone() };

    let mut table = Table::new(
        "Ablation — RL scheduler design choices (MATCHNET, 8 types, median of 3 seeds)",
        &["variant", "best cost ($)", "sched time (s)"],
    );

    let b1 = budget.clone();
    let (c, t) = run(&move |s| RlScheduler::lstm(b1.clone(), s), &cm, &seeds);
    table.row(&["LSTM policy + baseline (ours)".into(), format!("{c:.3}"), format!("{t:.2}")]);

    let b2 = budget.clone();
    let (c, t) = run(&move |s| RlScheduler::rnn(b2.clone(), s), &cm, &seeds);
    table.row(&["Elman RNN policy".into(), format!("{c:.3}"), format!("{t:.2}")]);

    let b3 = budget.clone();
    let (c, t) = run(&move |s| RlScheduler::tabular(b3.clone(), s), &cm, &seeds);
    table.row(&["tabular policy (no inter-layer state)".into(), format!("{c:.3}"), format!("{t:.2}")]);

    let b4 = no_baseline;
    let (c, t) = run(&move |s| RlScheduler::lstm(b4.clone(), s), &cm, &seeds);
    table.row(&["LSTM, frozen baseline (moving avg ablated)".into(), format!("{c:.3}"), format!("{t:.2}")]);

    table.emit("ablation_rl");
}
