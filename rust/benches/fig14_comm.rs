//! Figure 14 (extension): throughput and bytes-on-wire of the async comm
//! fabric, swept over workers x gradient codec x staleness bound, against
//! two synchronous baselines:
//!
//! 1. the fabric's own bulk-synchronous single-threaded reference
//!    (`comm::run_sync_reference`) on the *identical* workload — the
//!    apples-to-apples comparator for every sweep cell; and
//! 2. `train::sync_baseline::SyncBaselineRuntime` executing the matching
//!    embedding-front + dense-tower stage pipeline in-process — the
//!    monolithic "TF-style" runtime of Figure 12, showing what the fabric
//!    buys over a runtime with no worker parallelism at all.
//!
//! Expected shape: at staleness >= 1 the async engine's throughput is at
//! least the synchronous baseline's (and grows with workers), SparseF16
//! moves measurably fewer bytes than F32, and staleness 0 stays
//! bit-identical to the reference (asserted here, not just reported).

use heterps::comm::{run_async, run_sync_reference, CommConfig};
use heterps::data::compress::Codec;
use heterps::metrics::Table;
use heterps::resources::paper_testbed;
use heterps::train::stage::{
    BackwardOut, EmbeddingStage, MicroBatch, StageOp, Tensor, EMB_DIM, SLOTS, X_DIM,
};
use heterps::train::sync_baseline::SyncBaselineRuntime;
use heterps::train::ParamServer;
use heterps::util::rng::Rng;
use std::sync::Arc;

fn sweep_config(workers: usize, codec: Codec, staleness: u64) -> CommConfig {
    CommConfig {
        workers,
        steps: 20,
        rows: 64,
        slots: 8,
        dim: 16,
        vocab: 20_000,
        staleness,
        codec,
        compute_ms: 2.0,
        seed: 42,
        ..Default::default()
    }
}

fn store_for(cfg: &CommConfig) -> ParamServer {
    ParamServer::new(cfg.dim, 16, 0.3, cfg.seed)
}

/// A dense "tower" stand-in for the sync-baseline pipeline: burns the same
/// per-microbatch device time the engine emulates, originates the loss,
/// and hands the embedding stage an all-ones gradient.
struct DelayTowerStage {
    ms: f64,
}

impl StageOp for DelayTowerStage {
    fn name(&self) -> &str {
        "delay-tower"
    }
    fn forward(&mut self, mb: &MicroBatch, input: Option<&Tensor>) -> anyhow::Result<Tensor> {
        let _ = input;
        std::thread::sleep(std::time::Duration::from_secs_f64(self.ms / 1e3));
        Ok(Tensor::zeros(mb.labels.len(), 1))
    }
    fn backward(
        &mut self,
        mb: &MicroBatch,
        _input: Option<&Tensor>,
        _grad: Option<&Tensor>,
    ) -> anyhow::Result<BackwardOut> {
        std::thread::sleep(std::time::Duration::from_secs_f64(self.ms / 1e3));
        let rows = mb.labels.len();
        Ok(BackwardOut {
            dinput: Some(Tensor::from_vec(vec![1.0; rows * X_DIM], rows, X_DIM)),
            loss: Some(0.0),
        })
    }
    fn dense_grads_mut(&mut self) -> Option<&mut Vec<f32>> {
        None
    }
    fn apply_update(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
    fn set_speed_factor(&mut self, _f: f64) {}
}

/// Synthetic microbatches with the embedding-stage geometry.
fn microbatches(steps: usize, rows: usize, vocab: usize, seed: u64) -> Vec<MicroBatch> {
    let mut rng = Rng::new(seed);
    (0..steps)
        .map(|j| MicroBatch {
            index: j,
            sparse_ids: (0..rows * SLOTS).map(|_| rng.zipf(vocab, 1.05) as u32).collect(),
            labels: vec![0.0; rows],
        })
        .collect()
}

fn main() {
    // --- Sweep: workers x codec x staleness vs the sync reference. -----
    let pool = paper_testbed();
    let mut table = Table::new(
        "Figure 14 — async fabric: throughput and wire traffic (vs sync reference)",
        &[
            "workers",
            "codec",
            "staleness",
            "samples/s",
            "vs sync",
            "wire KB",
            "push ratio",
            "stale mean/max",
        ],
    );
    let mut all_at_least_sync = true;
    for &workers in &[2usize, 4, 8] {
        for codec in Codec::ALL {
            // One reference run per (workers, codec) cell group.
            let ref_cfg = sweep_config(workers, codec, 0);
            let sync = run_sync_reference(&ref_cfg, &store_for(&ref_cfg)).expect("sync ref");
            for &staleness in &[0u64, 1, 4] {
                let cfg = sweep_config(workers, codec, staleness);
                let store = store_for(&cfg);
                let report = run_async(&cfg, &pool, &store).expect("async run");
                if staleness == 0 {
                    assert_eq!(
                        report.digest, sync.digest,
                        "staleness 0 must be bit-identical to the sync reference \
                         (workers={workers}, codec={codec:?})"
                    );
                }
                let speedup = report.throughput / sync.throughput.max(1e-9);
                if staleness >= 1 && speedup < 1.0 {
                    all_at_least_sync = false;
                }
                table.row(&[
                    workers.to_string(),
                    codec.name().to_string(),
                    staleness.to_string(),
                    format!("{:.0}", report.throughput),
                    format!("{speedup:.2}x"),
                    format!("{:.1}", report.snapshot.wire_bytes_total() as f64 / 1e3),
                    format!("{:.2}x", report.snapshot.push_compression_ratio()),
                    format!(
                        "{:.2}/{}",
                        report.snapshot.staleness_mean, report.snapshot.staleness_max
                    ),
                ]);
            }
        }
    }
    table.emit("fig14_comm");
    println!(
        "staleness>=1 throughput >= sync reference in every cell: {}",
        all_at_least_sync
    );

    // --- Bytes check: SparseF16 vs F32 at fixed workers/staleness. -----
    let f32_cfg = sweep_config(4, Codec::F32, 1);
    let sp_cfg = sweep_config(4, Codec::SparseF16, 1);
    let f32_run = run_async(&f32_cfg, &pool, &store_for(&f32_cfg)).expect("f32 run");
    let sp_run = run_async(&sp_cfg, &pool, &store_for(&sp_cfg)).expect("sparse run");
    println!(
        "bytes-on-wire (4 workers, staleness 1): f32 {:.1} KB vs sparsef16 {:.1} KB ({:.2}x less)",
        f32_run.snapshot.wire_bytes_total() as f64 / 1e3,
        sp_run.snapshot.wire_bytes_total() as f64 / 1e3,
        f32_run.snapshot.wire_bytes_total() as f64
            / sp_run.snapshot.wire_bytes_total().max(1) as f64
    );
    assert!(
        sp_run.snapshot.push_wire_bytes < f32_run.snapshot.push_wire_bytes,
        "SparseF16 must reduce push bytes vs F32"
    );

    // --- The fabric vs the monolithic synchronous runtime (Fig 12's
    //     baseline) on matched geometry: EMB_DIM/SLOTS embedding front,
    //     the same emulated tower time, the same sample count per step. --
    let steps = 6usize;
    let rows = 256usize;
    let vocab = 50_000usize;
    let tower_ms = 4.0; // fwd + bwd = 8 ms, matching compute_ms below
    let mut t2 = Table::new(
        "Figure 14b — fabric vs train::sync_baseline (matched embedding geometry)",
        &["system", "workers", "staleness", "samples/s", "vs sync baseline"],
    );
    let ps = Arc::new(ParamServer::new(EMB_DIM, 16, 0.3, 42));
    let mut baseline = SyncBaselineRuntime::new(vec![
        Box::new(EmbeddingStage::new(ps)),
        Box::new(DelayTowerStage { ms: tower_ms }),
    ]);
    for mb in microbatches(steps, rows, vocab, 7) {
        baseline.train_step(std::slice::from_ref(&mb)).expect("baseline step");
    }
    let base_thr = baseline.stats.throughput();
    t2.row(&[
        "sync baseline (in-process)".into(),
        "1".into(),
        "-".into(),
        format!("{base_thr:.0}"),
        "1.00x".into(),
    ]);
    for &staleness in &[0u64, 1] {
        let cfg = CommConfig {
            workers: 4,
            steps,
            rows,
            slots: SLOTS,
            dim: EMB_DIM,
            vocab,
            staleness,
            codec: Codec::F32,
            compute_ms: 2.0 * tower_ms,
            seed: 42,
            ..Default::default()
        };
        let store = ParamServer::new(cfg.dim, 16, 0.3, cfg.seed);
        let report = run_async(&cfg, &pool, &store).expect("matched async run");
        t2.row(&[
            "async fabric".into(),
            "4".into(),
            staleness.to_string(),
            format!("{:.0}", report.throughput),
            format!("{:.2}x", report.throughput / base_thr.max(1e-9)),
        ]);
    }
    t2.emit("fig14_comm_vs_sync_baseline");

    // --- Fig 14c: recovery overhead of elastic membership vs the
    //     fixed-membership baseline. The virtual-clock engine replays the
    //     same workload under scripted kill/restart faults; every row is
    //     bit-reproducible, so the overhead numbers are exact, not
    //     sampled. ------------------------------------------------------
    use heterps::comm::{run_membership, FaultPlan};
    use heterps::obs::Tracer;
    let mut t3 = Table::new(
        "Figure 14c — membership engine: recovery overhead vs fixed membership (virtual clock)",
        &["fault plan", "virtual s", "samples/s", "evictions", "joins", "recovery s", "vs fixed"],
    );
    let mcfg = sweep_config(4, Codec::SparseF16, 1);
    let plans = [
        ("none", FaultPlan::empty()),
        (
            "kill:1@5,restart:1@10",
            FaultPlan::parse("kill:1@5,restart:1@10", mcfg.workers, mcfg.steps, mcfg.seed)
                .expect("scripted plan"),
        ),
        (
            "seed:7",
            FaultPlan::parse("seed:7", mcfg.workers, mcfg.steps, mcfg.seed).expect("seeded plan"),
        ),
    ];
    let mut fixed_secs = 0.0f64;
    for (name, plan) in &plans {
        let r = run_membership(&mcfg, &pool, &store_for(&mcfg), plan, &Tracer::disabled())
            .expect("membership run");
        let again = run_membership(&mcfg, &pool, &store_for(&mcfg), plan, &Tracer::disabled())
            .expect("membership replay");
        assert_eq!(r.digest, again.digest, "{name}: replay must be bit-identical");
        assert_eq!(
            r.virtual_secs.to_bits(),
            again.virtual_secs.to_bits(),
            "{name}: virtual clock must be bit-identical"
        );
        if *name == "none" {
            fixed_secs = r.virtual_secs;
        }
        t3.row(&[
            name.to_string(),
            format!("{:.4}", r.virtual_secs),
            format!("{:.0}", r.throughput),
            r.server.evictions.to_string(),
            r.server.joins.to_string(),
            format!("{:.4}", r.snapshot.recovery_secs),
            format!("{:+.1}%", (r.virtual_secs / fixed_secs.max(1e-12) - 1.0) * 100.0),
        ]);
        if r.server.joins > 0 {
            assert!(
                r.snapshot.recovery_secs > 0.0,
                "{name}: a rejoin handoff must pay recovery time"
            );
        }
    }
    t3.emit("fig14_membership_recovery");
}
