//! Table 2: scheduling time of Brute Force vs RL as layers grow.
//!
//! BF(2)/BF(4) enumerate `T^L` plans; RL's time is flat. Exactly as in the
//! paper, BF(4) beyond 12 layers is *estimated* ("E") by extrapolating the
//! measured per-plan evaluation rate (the paper did the same at 16 layers
//! and gave up at 20), and RL finds the same optimum as BF wherever BF is
//! tractable.
//!
//! A second table reports the *anytime* view the session API enables:
//! each method's incumbent cost after 10 / 100 / 1k cost-model
//! evaluations on the 2-type pool — the paper's cost-under-a-budget story
//! in one place.

mod common;

use heterps::cost::{CostConfig, CostModel};
use heterps::metrics::Table;
use heterps::model::zoo::ctrdnn_with_layers;
use heterps::resources::simulated_types;
use heterps::sched::bruteforce::BruteForce;
use heterps::sched::rl::{RlConfig, RlScheduler};
use heterps::sched::Scheduler;
use heterps::util::fmt_secs;

const MILESTONES: [usize; 3] = [10, 100, 1000];

fn main() {
    let mut table = Table::new(
        "Table 2 — scheduling time (s): BF vs RL",
        &["layers", "BF(2)", "BF(4)", "RL", "RL cost == BF(2) cost"],
    );
    // Budget for exact BF enumeration before switching to estimation.
    let exact_cap: usize = 2_000_000;

    // Warm the PJRT executable cache so the first RL row doesn't carry the
    // one-time policy-artifact compilation (~10 s) the later rows skip.
    {
        let model = ctrdnn_with_layers(8);
        let pool = simulated_types(2, true);
        let cm = CostModel::new(&model, &pool, CostConfig::default());
        let warm = RlConfig { rounds: 1, samples_per_round: 1, ..Default::default() };
        let _ = RlScheduler::lstm(warm, 1).schedule(&cm);
    }

    let mut anytime = Table::new(
        "Table 2b — incumbent cost ($) at 10/100/1k evaluations (2 types)",
        &["layers", "BF @10/100/1k", "RL @10/100/1k"],
    );

    for layers in [8usize, 12, 16, 20] {
        let model = ctrdnn_with_layers(layers);
        let mut cells: Vec<String> = vec![layers.to_string()];
        let mut bf2_cost = None;

        for types in [2usize, 4] {
            let pool = simulated_types(types, true);
            let cm = CostModel::new(&model, &pool, CostConfig::default());
            let space = BruteForce::search_space(layers, types);
            if space <= exact_cap as f64 {
                let out = BruteForce::new().schedule(&cm);
                if types == 2 {
                    bf2_cost = Some(out.eval.cost_usd);
                }
                cells.push(fmt_secs(out.wall_time.as_secs_f64()));
            } else if space <= 1e12 {
                // Measure the evaluation rate on a capped run, extrapolate.
                let probe = BruteForce::with_cap(20_000).schedule(&cm);
                let rate = probe.evaluations as f64 / probe.wall_time.as_secs_f64();
                cells.push(format!("{}(E)", fmt_secs(space / rate)));
            } else {
                cells.push("/".into());
            }
        }

        let pool = simulated_types(2, true);
        let cm = CostModel::new(&model, &pool, CostConfig::default());
        let mut rl = RlScheduler::lstm(RlConfig::default(), 42);
        let out = rl.schedule(&cm);
        cells.push(fmt_secs(out.wall_time.as_secs_f64()));
        cells.push(match bf2_cost {
            Some(b) => {
                if out.eval.cost_usd <= b * 1.001 {
                    "yes".into()
                } else {
                    format!("no ({:.1}% off)", (out.eval.cost_usd / b - 1.0) * 100.0)
                }
            }
            None => "-".into(),
        });
        table.row(&cells);

        // Anytime curves: same model, same 2-type pool, budgeted sessions.
        let bf_curve =
            common::anytime_costs("bf", &model, &pool, 20_000.0, 42, &MILESTONES);
        let rl_curve =
            common::anytime_costs("rl", &model, &pool, 20_000.0, 42, &MILESTONES);
        anytime.row(&[
            layers.to_string(),
            common::fmt_curve(&bf_curve),
            common::fmt_curve(&rl_curve),
        ]);
    }
    table.emit("table2_bf_vs_rl");
    anytime.emit("table2_anytime");
}
