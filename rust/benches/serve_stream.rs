//! §Serve throughput bench: the streaming admission daemon end to end.
//!
//! A 1k-job steady stream on the 48-core contention pool, greedy
//! admissions: measure sustained admission throughput (decisions/sec)
//! and the decision-latency quantiles with the probe off and on, plus
//! the JSONL codec on a 10k-line stream. Every timed run must land on
//! the same admission digest — the bench doubles as a determinism check
//! at a scale the unit tests don't reach.
//!
//! Rows land in EXPERIMENTS.md §Serve and, machine-readably, in
//! `results/BENCH_perf.json` under the `serve_stream` bench (merged
//! alongside perf_hotpath's rows).

mod common;

use heterps::cluster::{steady_mix, tight_pool, ClusterConfig};
use heterps::metrics::{merge_bench_rows, BenchRow, Table};
use heterps::obs::WatchConfig;
use heterps::sched::SchedulerSpec;
use heterps::serve::{self, parse_stream, render_stream, ClockMode, ProbeConfig, ServeConfig};

fn main() {
    let pool = tight_pool();
    let seed = 42u64;
    let queue = steady_mix(1_000, seed, 20_000.0);
    let cfg = |probe: Option<ProbeConfig>| ServeConfig {
        cluster: ClusterConfig {
            spec: SchedulerSpec::parse("greedy").unwrap(),
            admit_budget_evals: 32,
            ..Default::default()
        },
        policy: "drf-cost".to_string(),
        probe,
        clock: ClockMode::Virtual,
        progress_every: 0,
        stats_every: 0,
        watch: None,
    };

    let mut table = Table::new("§Serve — streaming admission", &["op", "mean", "std", "unit"]);
    let mut rows: Vec<BenchRow> = Vec::new();
    let mut row = |table: &mut Table, name: &str, mean: f64, std: f64, unit: &str| {
        table.row(&[name.to_string(), format!("{mean:.3}"), format!("{std:.3}"), unit.to_string()]);
        rows.push(BenchRow::new(name, mean, std, unit));
    };

    // Probe off: the baseline serial daemon.
    let plain = cfg(None);
    let mut digest = None;
    let mut last = None;
    let (m, s) = common::time_it(1, 5, || {
        let out = serve::run_serve(&pool, &queue, &plain, seed).unwrap();
        match digest {
            None => digest = Some(out.admission_digest),
            Some(d) => assert_eq!(d, out.admission_digest, "serve run not deterministic"),
        }
        last = Some(out);
    });
    let out = last.take().expect("at least one run");
    row(&mut table, "serve.run 1k jobs (probe off)", m, s, "s");
    row(
        &mut table,
        "serve.admission_throughput (probe off)",
        out.decisions_per_sec,
        0.0,
        "decisions/s",
    );
    row(&mut table, "serve.decision_latency p50", out.report.lat_p50_us as f64, 0.0, "us");
    row(&mut table, "serve.decision_latency p95", out.report.lat_p95_us as f64, 0.0, "us");
    row(&mut table, "serve.decision_latency p99", out.report.lat_p99_us as f64, 0.0, "us");

    // Probe on: self-tuned concurrency, digest must not move.
    let probed = cfg(Some(ProbeConfig { window: 16, ..Default::default() }));
    let mut last = None;
    let (m, s) = common::time_it(1, 5, || {
        let out = serve::run_serve(&pool, &queue, &probed, seed).unwrap();
        assert_eq!(
            digest,
            Some(out.admission_digest),
            "the probe perturbed admission decisions"
        );
        last = Some(out);
    });
    let out = last.take().expect("at least one run");
    let p = out.probe.as_ref().expect("probe summary");
    row(
        &mut table,
        &format!(
            "serve.run 1k jobs (probe on, threads {} -> {})",
            p.initial_threads, p.final_threads
        ),
        m,
        s,
        "s",
    );
    row(
        &mut table,
        "serve.admission_throughput (probe on)",
        out.decisions_per_sec,
        0.0,
        "decisions/s",
    );

    // Watchdog on: the online detectors ride the [stats] snapshots, and
    // like the probe they must never move the digest.
    let mut watched = cfg(None);
    watched.stats_every = 50;
    watched.watch = Some(WatchConfig::default());
    let mut last = None;
    let (m, s) = common::time_it(1, 5, || {
        let out = serve::run_serve(&pool, &queue, &watched, seed).unwrap();
        assert_eq!(
            digest,
            Some(out.admission_digest),
            "the watchdog perturbed admission decisions"
        );
        last = Some(out);
    });
    let out = last.take().expect("at least one run");
    // Virtual-clock alerts only: deterministic, so the row name is stable
    // across reruns and bench-diff can match it.
    let alerts = out.alerts.as_ref().map_or(0, |a| a.iter().filter(|x| !x.wall).count());
    row(
        &mut table,
        &format!("serve.run 1k jobs (watchdog on, {alerts} virtual alert(s))"),
        m,
        s,
        "s",
    );

    // The JSONL codec on a 10k-line stream.
    let big = steady_mix(10_000, seed, 20_000.0);
    let text = render_stream(&big);
    let lines = text.lines().count() as f64;
    let (m, s) = common::time_it(2, 10, || {
        std::hint::black_box(parse_stream(&text).unwrap().len());
    });
    row(&mut table, "serve.stream_parse 10k lines", m / lines * 1e6, s / lines * 1e6, "us/line");
    let (m, s) = common::time_it(2, 10, || {
        std::hint::black_box(render_stream(&big).len());
    });
    row(&mut table, "serve.stream_render 10k lines", m / lines * 1e6, s / lines * 1e6, "us/line");

    table.emit("serve_stream");

    let path = std::path::Path::new("results/BENCH_perf.json");
    match merge_bench_rows(path, "serve_stream", &rows) {
        Ok(()) => println!("[results] wrote results/BENCH_perf.json"),
        Err(e) => eprintln!("warn: could not write results/BENCH_perf.json: {e}"),
    }
}
