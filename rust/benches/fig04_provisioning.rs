//! Figure 4: monetary cost of our load-balancing provisioner vs the static
//! ratio heuristics StaRatio (GPU:CPU = 1:6, [61]) and StaPSRatio
//! (1:6:6 with dedicated PS cores, [26]) on CTRDNN across throughput
//! floors. Expected shape: ours <= StaPSRatio <= StaRatio.

mod common;

use heterps::cost::{CostConfig, CostModel};
use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::plan::SchedulingPlan;
use heterps::provision::provision_static_ratio;
use heterps::resources::paper_testbed;
use heterps::sched::rl::{RlConfig, RlScheduler};
use heterps::sched::Scheduler;

fn main() {
    let model = zoo::ctrdnn();
    let pool = paper_testbed();
    let mut table = Table::new(
        "Figure 4 — provisioning cost (USD): ours vs static ratios (CTRDNN)",
        &["floor (samples/s)", "ours", "StaRatio", "StaPSRatio", "ours saves vs StaRatio"],
    );
    for floor in [5_000.0f64, 10_000.0, 20_000.0, 40_000.0] {
        let cfg = CostConfig { throughput_limit: floor, ..Default::default() };
        let cm = CostModel::new(&model, &pool, cfg);
        // The paper uses its RL scheduler for the plan, then compares
        // provisioning policies on it.
        let out = RlScheduler::lstm(RlConfig::default(), 42).schedule(&cm);
        let plan: SchedulingPlan = out.plan.clone();
        let ours = out.eval.cost_usd;
        let sta = provision_static_ratio(&cm, &plan, false).map(|e| e.cost_usd);
        let staps = provision_static_ratio(&cm, &plan, true).map(|e| e.cost_usd);
        let saving = sta.map(|s| format!("{:.1}%", (s - ours) / s * 100.0));
        table.row(&[
            format!("{floor:.0}"),
            format!("{ours:.2}"),
            sta.map(|c| format!("{c:.2}")).unwrap_or_else(|| "/".into()),
            staps.map(|c| format!("{c:.2}")).unwrap_or_else(|| "/".into()),
            saving.unwrap_or_else(|| "-".into()),
        ]);
    }
    table.emit("fig04_provisioning");
}
