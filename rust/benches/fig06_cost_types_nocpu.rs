//! Figure 6: the Figure-5 comparison without any CPU type in the pool
//! (accelerator-only catalogs). The CPU-only method degenerates to the
//! anchor accelerator, as in the paper's figure.

mod common;

use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;

fn main() {
    let model = zoo::matchnet();
    let mut columns = vec!["types"];
    columns.extend(common::methods());
    let mut table = Table::new("Figure 6 — normalized cost vs #types (no CPU)", &columns);
    for types in [2usize, 4, 8, 16, 32, 64] {
        let pool = simulated_types(types, false);
        let mut costs = Vec::new();
        for method in common::methods() {
            let out = common::run_method(method, &model, &pool, 20_000.0, 42);
            costs.push(if out.eval.feasible { out.eval.cost_usd } else { f64::NAN });
        }
        let valid: Vec<f64> = costs.iter().cloned().filter(|c| c.is_finite()).collect();
        let norm = common::normalize(&valid);
        let mut it = norm.into_iter();
        let mut cells = vec![types.to_string()];
        for c in &costs {
            cells.push(if c.is_finite() {
                format!("{:.2}", it.next().unwrap())
            } else {
                "inf".into()
            });
        }
        table.row(&cells);
    }
    table.emit("fig06_cost_types_nocpu");
}
