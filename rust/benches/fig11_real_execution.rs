//! Figure 11: "real execution" cost per model per method. The paper ran
//! the plans on its physical cluster; here the discrete-event simulator
//! replays each provisioned plan with stragglers + dispatch overheads
//! (DESIGN.md §Hardware-Adaptation). Expected shape: same ranking as the
//! analytic Figure 8, but costs inflated — most for CPU-heavy plans (the
//! paper saw up to 17.4x inflation on CPU from small-batch overheads).

mod common;

use heterps::cost::{CostConfig, CostModel};
use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;
use heterps::simulator::{simulate_plan, SimConfig};

fn main() {
    let mut columns = vec!["model"];
    columns.extend(common::methods());
    let mut table = Table::new(
        "Figure 11 — real-execution (DES) cost in USD per model",
        &columns,
    );
    let sim_cfg = SimConfig::default();
    for model_name in ["matchnet", "ctrdnn", "2emb", "nce"] {
        let model = zoo::by_name(model_name).unwrap();
        let pool = simulated_types(2, true);
        let cfg = CostConfig { throughput_limit: 20_000.0, ..Default::default() };
        let cm = CostModel::new(&model, &pool, cfg);
        let mut cells = vec![model_name.to_string()];
        for method in common::methods() {
            let out = common::run_method(method, &model, &pool, 20_000.0, 42);
            match simulate_plan(&cm, &out.plan, &sim_cfg, 42) {
                Some(sim) => cells.push(format!("{:.2}", sim.cost_usd)),
                None => cells.push("/".into()),
            }
        }
        table.row(&cells);
    }
    table.emit("fig11_real_execution");
}
