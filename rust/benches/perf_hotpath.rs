//! §Perf micro-benchmarks: the hot paths the optimization pass tracks.
//!
//! * cost-model evaluation (the inner loop of every scheduler)
//! * the eval engine: batched parallel evaluation (1/2/4/8 threads),
//!   cache-hit lookup, 50%-hit replay, incremental-vs-full evaluation
//! * provisioning (Newton search per plan)
//! * policy forward/step through PJRT (RL round latency)
//! * PS pull/push, ring-allreduce, compression (training-path primitives)
//!
//! Before/after numbers are recorded in EXPERIMENTS.md §Perf; alongside
//! the table, the run emits a machine-readable `results/BENCH_perf.json`.

mod common;

use heterps::cost::{CostConfig, CostModel};
use heterps::data::compress::{compress_f32, decompress_f32, Codec};
use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::plan::SchedulingPlan;
use heterps::resources::simulated_types;
use heterps::runtime::artifacts_dir;
use heterps::sched::rl::policy::{featurize, Policy, Sample};
use heterps::sched::EvalEngine;
use heterps::train::allreduce::ring_allreduce;
use heterps::train::ParamServer;
use heterps::util::rng::Rng;

fn main() {
    let mut table = Table::new(
        "§Perf hot paths",
        &["op", "mean", "std", "unit"],
    );
    let mut rows_json: Vec<(String, f64, f64, String)> = Vec::new();
    let mut row = |name: &str, mean: f64, std: f64, unit: &str| {
        table.row(&[name.to_string(), format!("{mean:.3}"), format!("{std:.3}"), unit.to_string()]);
        rows_json.push((name.to_string(), mean, std, unit.to_string()));
    };

    // Cost-model evaluation.
    let model = zoo::matchnet();
    let pool = simulated_types(4, true);
    let cm = CostModel::new(&model, &pool, CostConfig::default());
    let mut rng = Rng::new(1);
    let plans: Vec<SchedulingPlan> = (0..64)
        .map(|_| SchedulingPlan::new((0..16).map(|_| rng.below(4)).collect()))
        .collect();
    let mut i = 0;
    let (m, s) = common::time_it(50, 2000, || {
        let e = cm.evaluate(&plans[i % plans.len()]);
        std::hint::black_box(e.cost_usd);
        i += 1;
    });
    row("cost_model.evaluate (16 layers, 4 types)", m * 1e6, s * 1e6, "us");

    // Stage profile derivation alone.
    let plan = &plans[0];
    let (m, s) = common::time_it(50, 2000, || {
        for span in plan.stages() {
            std::hint::black_box(cm.stage_profile(&span));
        }
    });
    row("cost_model.stage_profiles", m * 1e6, s * 1e6, "us");

    // Eval engine: batched parallel evaluation, 64-plan batches. The
    // engine commits results in submission order, so the only thing the
    // thread count changes is wall-clock — exactly what this measures.
    let mut serial_batch = 0.0;
    for threads in [1usize, 2, 4, 8] {
        let engine = EvalEngine::new(&cm).with_threads(threads);
        let (m, s) = common::time_it(3, 60, || {
            std::hint::black_box(engine.compute_batch(&plans).len());
        });
        if threads == 1 {
            serial_batch = m;
        }
        row(
            &format!(
                "eval_engine.batch64 threads={threads} ({:.2}x vs serial)",
                serial_batch / m
            ),
            m * 1e6 / plans.len() as f64,
            s * 1e6 / plans.len() as f64,
            "us/plan",
        );
    }

    // Cache-hit lookup: the memoized fast path of revisited plans.
    let engine = EvalEngine::new(&cm);
    std::hint::black_box(engine.evaluate(&plans[0]).cost_usd); // prime
    let (m, s) = common::time_it(50, 5000, || {
        std::hint::black_box(engine.evaluate(&plans[0]).cost_usd);
    });
    row("eval_engine.cache_hit lookup", m * 1e9, s * 1e9, "ns");

    // 50%-cache-hit replay: a 128-plan stream in which every plan occurs
    // twice (the genetic-elite / warm-start revisit shape), against the
    // same stream evaluated with no cache reuse.
    let stream: Vec<&SchedulingPlan> =
        plans.iter().chain(plans.iter()).collect();
    let (m_cold, _) = common::time_it(2, 20, || {
        // `compute` bypasses the eval cache: all 128 are full evaluations.
        let engine = EvalEngine::new(&cm);
        for p in &plans {
            std::hint::black_box(engine.compute(p).cost_usd);
        }
        for p in &plans {
            std::hint::black_box(engine.compute(p).cost_usd);
        }
    });
    let (m_hit, s_hit) = common::time_it(2, 20, || {
        let engine = EvalEngine::new(&cm);
        for p in &stream {
            std::hint::black_box(engine.evaluate(p).cost_usd);
        }
    });
    row(
        &format!("eval_engine.replay128 50% hits ({:.2}x vs uncached)", m_cold / m_hit),
        m_hit * 1e3,
        s_hit * 1e3,
        "ms",
    );

    // Incremental delta-evaluation: re-profile only the 1-2 stages a
    // single-gene mutation touches, vs the full evaluator.
    let base = &plans[0];
    let base_stages = base.stages();
    let base_profs = cm.stage_profiles(&base_stages);
    let mut rng_mut = Rng::new(9);
    let mutants: Vec<SchedulingPlan> = (0..64)
        .map(|_| {
            let mut a = base.assignment.clone();
            let pos = rng_mut.below(a.len());
            a[pos] = rng_mut.below(4);
            SchedulingPlan::new(a)
        })
        .collect();
    let mut i = 0;
    let (m_full, _) = common::time_it(10, 500, || {
        std::hint::black_box(cm.evaluate(&mutants[i % mutants.len()]).cost_usd);
        i += 1;
    });
    let mut i = 0;
    let (m_delta, s_delta) = common::time_it(10, 500, || {
        let mutant = &mutants[i % mutants.len()];
        std::hint::black_box(
            cm.evaluate_delta(mutant, &base_stages, &base_profs).cost_usd,
        );
        i += 1;
    });
    row(
        &format!("eval_engine.delta_eval ({:.2}x vs full)", m_full / m_delta),
        m_delta * 1e6,
        s_delta * 1e6,
        "us",
    );

    // PS pull/push (26 slots x 256 rows, dim 64).
    let ps = ParamServer::new(64, 32, 0.1, 3);
    let ids: Vec<u32> = (0..26 * 256).map(|j| (j * 7919 % 100_000) as u32).collect();
    let grads = vec![0.01f32; ids.len() * 64];
    let (m, s) = common::time_it(3, 50, || {
        std::hint::black_box(ps.pull(&ids));
    });
    row("ps.pull (6656 rows x 64)", m * 1e3, s * 1e3, "ms");
    let (m, s) = common::time_it(3, 50, || {
        ps.push(&ids, &grads);
    });
    row("ps.push (6656 rows x 64)", m * 1e3, s * 1e3, "ms");

    // Ring allreduce, 4 ranks x 1M floats.
    let (m, s) = common::time_it(1, 10, || {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; 1_000_000]).collect();
        ring_allreduce(&mut bufs);
        std::hint::black_box(bufs[0][0]);
    });
    row("ring_allreduce (4 x 1M f32)", m * 1e3, s * 1e3, "ms");

    // Compression codecs, 1M floats (10% dense).
    let mut rng = Rng::new(4);
    let data: Vec<f32> = (0..1_000_000)
        .map(|_| if rng.chance(0.1) { rng.f32() - 0.5 } else { 0.0 })
        .collect();
    for codec in [Codec::F32, Codec::F16, Codec::SparseF16] {
        let frame = compress_f32(&data, codec);
        let label = format!("compress {:?} (1M f32, ratio {:.1}x)", codec, 4e6 / frame.len() as f64);
        let (m, s) = common::time_it(1, 10, || {
            std::hint::black_box(compress_f32(&data, codec).len());
        });
        row(&label, m * 1e3, s * 1e3, "ms");
        let (m, s) = common::time_it(1, 10, || {
            std::hint::black_box(decompress_f32(&frame).unwrap().len());
        });
        row(&format!("decompress {codec:?}"), m * 1e3, s * 1e3, "ms");
    }

    // Policy fwd/step through PJRT (needs artifacts).
    if artifacts_dir().join("policy_lstm_fwd.hlo.txt").exists() {
        let mut rng = Rng::new(5);
        let mut pol = heterps::runtime::policy::HloPolicy::load_lstm(&mut rng).unwrap();
        let feats = featurize(&cm);
        let (m, s) = common::time_it(3, 50, || {
            std::hint::black_box(pol.probs(&feats).len());
        });
        row("policy_lstm.probs (PJRT)", m * 1e3, s * 1e3, "ms");
        let actions: Vec<usize> = (0..feats.num_layers).map(|l| l % 4).collect();
        let (m, s) = common::time_it(3, 50, || {
            pol.update(&feats, &[Sample { actions: actions.clone(), advantage: 0.1 }], 0.1);
        });
        row("policy_lstm.step (PJRT)", m * 1e3, s * 1e3, "ms");
    } else {
        eprintln!("(policy PJRT rows skipped: run `make artifacts`)");
    }

    table.emit("perf_hotpath");

    // Machine-readable artifact for EXPERIMENTS.md §Perf tracking. The
    // merge keeps other benches' rows (serve_stream shares the file).
    let rows: Vec<heterps::metrics::BenchRow> = rows_json
        .iter()
        .map(|(name, mean, std, unit)| heterps::metrics::BenchRow::new(name, *mean, *std, unit))
        .collect();
    let path = std::path::Path::new("results/BENCH_perf.json");
    match heterps::metrics::merge_bench_rows(path, "perf_hotpath", &rows) {
        Ok(()) => println!("[results] wrote results/BENCH_perf.json"),
        Err(e) => eprintln!("warn: could not write results/BENCH_perf.json: {e}"),
    }
}
