//! Figure 10: normalized throughput per model per method. The paper's
//! signature detail: every bar sits at/above 1.0 *except CPU-only on
//! CTRDNN*, where the CPU pool limit makes the floor unreachable — the
//! same violation should reproduce here (marked `*`).

mod common;

use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;

fn main() {
    let floor = 20_000.0;
    let mut columns = vec!["model"];
    columns.extend(common::methods());
    let mut table =
        Table::new("Figure 10 — normalized throughput per model (* = floor violated)", &columns);
    for model_name in ["matchnet", "ctrdnn", "2emb", "nce"] {
        let model = zoo::by_name(model_name).unwrap();
        let pool = simulated_types(4, true);
        let mut cells = vec![model_name.to_string()];
        for method in common::methods() {
            let out = common::run_method(method, &model, &pool, floor, 42);
            let norm = out.eval.throughput / floor;
            cells.push(if out.eval.feasible {
                format!("{norm:.2}")
            } else {
                format!("{norm:.2}*")
            });
        }
        table.row(&cells);
    }
    table.emit("fig10_throughput_models");
}
