//! Shared bench-harness helpers (criterion is unavailable offline; this
//! provides the warmup/repeat/summarize loop the benches share, plus the
//! §6.2 method runner used by the figure benches).

#![allow(dead_code)]

use heterps::cost::{CostConfig, CostModel};
use heterps::model::ModelSpec;
use heterps::resources::ResourcePool;
use heterps::sched::{self, ScheduleOutcome};
use heterps::util::stats::{mean, stddev};
use std::time::Instant;

/// Time `f` with `warmup` + `reps` runs; returns (mean, std) in seconds.
pub fn time_it<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    (mean(&samples), stddev(&samples))
}

/// Run one named scheduler on a (model, pool) pair with the default cost
/// config except the given floor; the RL variants fall back to tabular
/// automatically when artifacts are missing.
pub fn run_method(
    method: &str,
    model: &ModelSpec,
    pool: &ResourcePool,
    throughput_limit: f64,
    seed: u64,
) -> ScheduleOutcome {
    let cfg = CostConfig { throughput_limit, ..Default::default() };
    let cm = CostModel::new(model, pool, cfg);
    let mut s = sched::by_name(method, seed).unwrap_or_else(|| panic!("scheduler {method}"));
    s.schedule(&cm)
}

/// The §6.2 comparison methods in paper order.
pub fn methods() -> &'static [&'static str] {
    sched::comparison_methods()
}

/// Normalize a cost column by its minimum (the paper's figures normalize
/// "by multiplying a constant value for the sake of easy comparison").
pub fn normalize(costs: &[f64]) -> Vec<f64> {
    let min = costs.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-12);
    costs.iter().map(|c| c / min).collect()
}
