//! Shared bench-harness helpers (criterion is unavailable offline; this
//! provides the warmup/repeat/summarize loop the benches share, plus the
//! §6.2 method runner used by the figure benches).
//!
//! Methods are named by registry spec strings (`rl`, `bo:init=8`, ...), so
//! every bench records exactly the configuration that ran, and the
//! session-based [`anytime_costs`] helper produces the per-budget
//! incumbent curves of the Table 2/3 reworks.

#![allow(dead_code)]

use heterps::cost::{CostConfig, CostModel};
use heterps::model::ModelSpec;
use heterps::resources::ResourcePool;
use heterps::sched::{self, Budget, ScheduleOutcome, SchedulerSpec};
use heterps::util::stats::{mean, stddev};
use std::time::Instant;

/// Time `f` with `warmup` + `reps` runs; returns (mean, std) in seconds.
pub fn time_it<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    (mean(&samples), stddev(&samples))
}

fn parse_spec(spec: &str) -> SchedulerSpec {
    SchedulerSpec::parse(spec).unwrap_or_else(|e| panic!("bad scheduler spec `{spec}`: {e}"))
}

/// Run one scheduler spec on a (model, pool) pair with the default cost
/// config except the given floor; the RL variants fall back to tabular
/// automatically when artifacts are missing.
pub fn run_method(
    spec: &str,
    model: &ModelSpec,
    pool: &ResourcePool,
    throughput_limit: f64,
    seed: u64,
) -> ScheduleOutcome {
    let cfg = CostConfig { throughput_limit, ..Default::default() };
    let cm = CostModel::new(model, pool, cfg);
    parse_spec(spec).build(seed).schedule(&cm)
}

/// Incumbent cost after *exactly at most* `m` evaluations, for each
/// milestone `m` — the anytime curve of the Table 2/3 reworks. Each
/// milestone gets its own `Budget::evals(m)` session (searches are
/// deterministic per seed, so the runs are prefixes of one search);
/// sampling one coarse-stepping session instead would smear later-budget
/// costs into earlier milestones. `None` marks an infeasible milestone
/// (zero-evaluation budget).
pub fn anytime_costs(
    spec: &str,
    model: &ModelSpec,
    pool: &ResourcePool,
    throughput_limit: f64,
    seed: u64,
    milestones: &[usize],
) -> Vec<Option<f64>> {
    let cfg = CostConfig { throughput_limit, ..Default::default() };
    let cm = CostModel::new(model, pool, cfg);
    let spec = parse_spec(spec);
    milestones
        .iter()
        .map(|&at| {
            let scheduler = spec.build(seed);
            let mut session = scheduler.session(&cm, Budget::evals(at));
            sched::drive(session.as_mut(), None).ok().map(|out| out.eval.cost_usd)
        })
        .collect()
}

/// Render an anytime curve as a table cell: `a / b / c`, with `/` for
/// milestones no session could reach (zero-evaluation budget).
pub fn fmt_curve(costs: &[Option<f64>]) -> String {
    costs
        .iter()
        .map(|c| c.map(|v| format!("{v:.2}")).unwrap_or_else(|| "/".into()))
        .collect::<Vec<_>>()
        .join(" / ")
}

/// The §6.2 comparison methods in paper order (from the registry).
pub fn methods() -> Vec<&'static str> {
    sched::comparison_methods()
}

/// Normalize a cost column by its minimum (the paper's figures normalize
/// "by multiplying a constant value for the sake of easy comparison").
pub fn normalize(costs: &[f64]) -> Vec<f64> {
    let min = costs.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-12);
    costs.iter().map(|c| c / min).collect()
}
