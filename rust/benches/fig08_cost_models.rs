//! Figure 8: normalized training cost per model (MATCHNET, CTRDNN, 2EMB,
//! NCE) per scheduling method, CPU included. Expected shape: RL lowest on
//! every model; BO close on the small models (NCE/2EMB) but off on the
//! complex ones; GPU-only and Heuristic pay the accelerator premium.

mod common;

use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;

fn main() {
    let mut columns = vec!["model"];
    columns.extend(common::methods());
    let mut table = Table::new("Figure 8 — normalized cost per model (with CPU)", &columns);
    for model_name in ["matchnet", "ctrdnn", "2emb", "nce"] {
        let model = zoo::by_name(model_name).unwrap();
        let pool = simulated_types(4, true);
        let mut costs = Vec::new();
        for method in common::methods() {
            let out = common::run_method(method, &model, &pool, 20_000.0, 42);
            costs.push(if out.eval.feasible { out.eval.cost_usd } else { f64::NAN });
        }
        let valid: Vec<f64> = costs.iter().cloned().filter(|c| c.is_finite()).collect();
        let norm = common::normalize(&valid);
        let mut it = norm.into_iter();
        let mut cells = vec![model_name.to_string()];
        for c in &costs {
            cells.push(if c.is_finite() { format!("{:.2}", it.next().unwrap()) } else { "inf".into() });
        }
        table.row(&cells);
    }
    table.emit("fig08_cost_models");
}
