//! Figure 7: normalized throughput (throughput / floor) per scheduling
//! method. Every feasible plan must sit at >= 1.0 — the provisioner
//! enforces the constraint regardless of which scheduler chose the plan.

mod common;

use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;

fn main() {
    let model = zoo::matchnet();
    let floor = 20_000.0;
    let mut columns = vec!["types"];
    columns.extend(common::methods());
    let mut table = Table::new("Figure 7 — normalized throughput (>= 1.0 means floor met)", &columns);
    for types in [2usize, 4, 8, 16] {
        let pool = simulated_types(types, true);
        let mut cells = vec![types.to_string()];
        for method in common::methods() {
            let out = common::run_method(method, &model, &pool, floor, 42);
            let norm = out.eval.throughput / floor;
            cells.push(if out.eval.feasible {
                format!("{norm:.2}")
            } else {
                format!("{norm:.2}*") // * = constraint violated (pool limit)
            });
        }
        table.row(&cells);
    }
    table.emit("fig07_throughput");
}
