//! Figure 13 (ours, beyond the paper): reactive adaptation policies on
//! elastic workload traces. For every shipped trace and a spread of
//! scheduler methods, replay the trace under never-adapt (static peak
//! provisioning, the §6.1 baseline generalized over time),
//! re-schedule-from-scratch, and warm-started budget-capped rescheduling.
//! Expected shape: warm-start matches from-scratch on SLA damage at a
//! fraction of the evaluations, and both beat never-adapt on cumulative
//! cost whenever the trace has a trough to exploit.

use heterps::elastic::{self, ControllerConfig, EpisodeReport, TraceConfig};
use heterps::metrics::Table;
use heterps::model::zoo;
use heterps::resources::simulated_types;
use heterps::sched::SchedulerSpec;

fn main() {
    let model = zoo::ctrdnn();
    let pool = simulated_types(2, true);
    let seed = 42u64;
    let tcfg = TraceConfig { ticks: 24, ..Default::default() };
    let ctl = ControllerConfig::default();

    let mut columns = vec!["trace", "method"];
    columns.extend_from_slice(&EpisodeReport::TABLE_COLUMNS);
    let mut table = Table::new(
        "Figure 13 — elastic adaptation: policy comparison per trace and method",
        &columns,
    );
    for trace_name in elastic::trace::names() {
        let trace = elastic::trace::by_name(trace_name, &tcfg, seed).unwrap();
        for spec_str in ["rl", "genetic", "greedy"] {
            let spec = SchedulerSpec::parse(spec_str).unwrap();
            let reports = elastic::run_all_policies(&model, &pool, &spec, &trace, &ctl, seed)
                .unwrap_or_else(|e| panic!("{trace_name}/{spec_str}: {e}"));
            for r in &reports {
                let mut row = vec![trace_name.to_string(), spec_str.to_string()];
                row.extend(r.table_row());
                table.row(&row);
            }
        }
    }
    table.emit("fig13_elastic");
}
