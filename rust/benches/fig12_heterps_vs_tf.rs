//! Figure 12: training throughput of HeterPS (pipelined, heterogeneous)
//! vs the synchronous monolithic baseline ("TF" in the paper; here the
//! SyncBaselineRuntime executing the identical stage ops — DESIGN.md
//! §Hardware-Adaptation) on CTRDNN1 (IO-heavy) and CTRDNN2-like load
//! (compute-heavy).
//!
//! Heterogeneity is emulated with per-stage speed factors: a "CPU"
//! deployment slows the dense tower, a "GPU" deployment slows the sparse
//! front (accelerators are poor at sparse lookups over PCIe), and HeterPS
//! places each stage on its best resource (no slowdown) *and* pipelines.
//!
//! Requires `make artifacts`. Expected shape, as in the paper:
//!   HeterPS > HeterPS-CPU/GPU > TF-CPU/GPU (several-fold).

use heterps::data::dataset::{CtrDataset, DatasetConfig};
use heterps::metrics::Table;
use heterps::runtime::artifacts_dir;
use heterps::train::pipeline::{PipelineConfig, PipelineTrainer};
use heterps::train::stage::{EmbeddingStage, HloStage, StageOp, EMB_DIM, MB_ROWS, SLOTS};
use heterps::train::sync_baseline::SyncBaselineRuntime;
use heterps::train::ParamServer;
use std::sync::Arc;

/// Per-microbatch *device* time (ms) of (embedding, tower, head) under a
/// deployment, added on top of the real (host) HLO execution. Absolute
/// delays emulate what each stage would cost on its assigned resource —
/// sparse lookups are cheap on CPUs and terrible over PCIe on GPUs; wide
/// GEMMs are the reverse — without the host-contention noise a
/// multiplicative factor amplifies. See DESIGN.md §Hardware-Adaptation.
fn device_profile(config: &str) -> (f64, f64, f64) {
    match config {
        "cpu" => (15.0, 50.0, 40.0),  // dense tower crawls on CPU cores
        "gpu" => (45.0, 10.0, 8.0),   // sparse pulls crawl over PCIe
        _ => (15.0, 10.0, 8.0),       // heterogeneous: each stage at its best
    }
}

fn stages(profile: (f64, f64, f64), lr: f32) -> Vec<Box<dyn StageOp>> {
    let (emb_ms, s1_ms, s2_ms) = profile;
    let ps = Arc::new(ParamServer::new(EMB_DIM, 16, lr, 7));
    let mut emb = EmbeddingStage::new(ps);
    emb.set_extra_delay_ms(emb_ms);
    let mut s1 = HloStage::ctr_stage1(lr, 31).expect("artifacts");
    s1.set_extra_delay_ms(s1_ms);
    let mut s2 = HloStage::ctr_stage2(lr, 32).expect("artifacts");
    s2.set_extra_delay_ms(s2_ms);
    vec![Box::new(emb), Box::new(s1), Box::new(s2)]
}

fn run(runtime: &str, config: &str, steps: usize, microbatches: usize) -> f64 {
    let profile = device_profile(config);
    let mut ds = CtrDataset::new(
        DatasetConfig { slots: SLOTS, vocab: 50_000, ..Default::default() },
        13,
    );
    let thr;
    if runtime == "pipeline" {
        let mut t = PipelineTrainer::new(stages(profile, 0.2), PipelineConfig { microbatches });
        for _ in 0..steps {
            let b = ds.next_batch(microbatches * MB_ROWS);
            let mbs = PipelineTrainer::microbatches(&b, SLOTS);
            t.train_step(&mbs).expect("step");
        }
        thr = t.stats.throughput();
    } else {
        let mut t = SyncBaselineRuntime::new(stages(profile, 0.2));
        for _ in 0..steps {
            let b = ds.next_batch(microbatches * MB_ROWS);
            let mbs = PipelineTrainer::microbatches(&b, SLOTS);
            t.train_step(&mbs).expect("step");
        }
        thr = t.stats.throughput();
    }
    thr
}

fn main() {
    if !artifacts_dir().join("ctr_stage1_fwd.hlo.txt").exists() {
        eprintln!("fig12: artifacts not built — run `make artifacts` first");
        return;
    }
    let steps = 5;
    let microbatches = 8;
    let mut table = Table::new(
        "Figure 12 — throughput (samples/s): HeterPS vs sync baseline",
        &["system", "deployment", "samples/s", "vs TF same-deployment"],
    );
    let tf_cpu = run("sync", "cpu", steps, microbatches);
    let tf_gpu = run("sync", "gpu", steps, microbatches);
    let h_cpu = run("pipeline", "cpu", steps, microbatches);
    let h_gpu = run("pipeline", "gpu", steps, microbatches);
    let h_het = run("pipeline", "hetero", steps, microbatches);
    let rows = [
        ("TF-CPU (sync)", "cpu", tf_cpu, 1.0),
        ("TF-GPU (sync)", "gpu", tf_gpu, 1.0),
        ("HeterPS-CPU", "cpu", h_cpu, h_cpu / tf_cpu),
        ("HeterPS-GPU", "gpu", h_gpu, h_gpu / tf_gpu),
        ("HeterPS (hetero)", "cpu+gpu", h_het, h_het / tf_cpu.min(tf_gpu)),
    ];
    for (name, dep, thr, speedup) in rows {
        table.row(&[
            name.to_string(),
            dep.to_string(),
            format!("{thr:.0}"),
            format!("{speedup:.1}x"),
        ]);
    }
    table.emit("fig12_heterps_vs_tf");
}
